//! The declarative scenario grid: **one** struct describing every
//! sweep axis × workload kind, replacing the three parallel
//! config/sweep stacks the campaign harness used to carry
//! (`CampaignConfig` / `EventCampaignConfig` / `CogCampaignConfig`
//! each hand-rolled its own nested loops and cell structs).
//!
//! A [`Grid`] is [`Axes`] (the swept dimensions — workload [`Kind`],
//! coupling [`Topology`], pool [`Fleet`] composition, routing
//! [`Policy`], rank count, arrival process, batching window,
//! models-per-rank, swap cost, overlap, fabric oversubscription) plus
//! [`Knobs`] (the scalar workload parameters every cell shares).
//! [`Grid::cells`] expands it into [`Scenario`] cells in a fixed
//! nesting order — the same order the legacy per-mode sweeps used, so
//! the committed goldens are byte-stable across the refactor.
//!
//! Axes that cannot apply to a cell collapse instead of multiplying:
//! the all-local topology has no shared fabric, so the
//! oversubscription axis collapses to the single 1:1 cell and the
//! fleet axis to the default pool (there is no pool to compose); an
//! axis a cell's *kind* cannot observe (arrivals outside the event
//! kind; models/swap/overlap outside the cog and fluid kinds;
//! batching windows in the analytic kind; timed controls outside the
//! event-driven kinds) collapses to its first value rather than
//! re-running identical cells.
//!
//! The **fleet axis** is the grid's proof of life: heterogeneous
//! mixed GPU+RDU pools ([`Fleet::Mixed`], e.g. `4g2r` = four pooled
//! A100s next to two RDU tile groups) ride every mode — analytic,
//! event, coupled — from this single definition, where previously a
//! new axis needed three hand-wired copies.
//!
//! The legacy config structs remain as thin typed views
//! ([`CampaignConfig::grid`], [`EventCampaignConfig::grid`],
//! [`CogCampaignConfig::grid`]) so existing callers and the committed
//! golden JSON keep working unchanged.

use crate::cluster::{Backend, GpuBackend, Policy, RduBackend};
use crate::devices::{profiles, Api, Gpu, ModelProfile};
use crate::eventsim::{ArrivalProcess, AutoscalerCfg, FleetAction, FleetEvent};
use crate::fabric::{FabricSpec, Topology as NetTopology};
use crate::netsim::Link;
use crate::rdu::RduApi;

/// The three coupling topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    Local,
    Pooled,
    Hybrid,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Local, Topology::Pooled, Topology::Hybrid];

    pub fn label(&self) -> &'static str {
        match self {
            Topology::Local => "per-rank local GPUs",
            Topology::Pooled => "shared disaggregated accelerator pool",
            Topology::Hybrid => "hybrid (MIR local, Hermit pooled)",
        }
    }

    /// Stable snake_case key for JSON artifacts.
    pub fn key(&self) -> &'static str {
        match self {
            Topology::Local => "local",
            Topology::Pooled => "pooled",
            Topology::Hybrid => "hybrid",
        }
    }

    /// Does this topology have backends behind the shared fabric?
    /// Local is all node-local: the oversubscription and fleet axes
    /// collapse to a single cell there (no duplicate sweep cells).
    pub fn pays_the_link(&self) -> bool {
        !matches!(self, Topology::Local)
    }
}

/// What backs the shared pool — the heterogeneous-fleet axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fleet {
    /// The legacy pool: one full 4-tile group on the optimised C++
    /// stack next to a half-provisioned 2-tile group still on the
    /// naive Python stack (the allocator's natural shapes, Fig. 13's
    /// API spread).
    DefaultPool,
    /// A mixed pool: `gpus` A100/TRT-CudaGraphs members next to
    /// `rdus` RDU tile groups (alternating 4-tile C++ / 2-tile
    /// Python), all behind the same fabric — the heterogeneous fleet
    /// the paper's §VI leaves open.  (u16: the fluid scale campaign
    /// sweeps pools up to 512 members.)
    Mixed { gpus: u16, rdus: u16 },
}

impl Fleet {
    /// Stable key for JSON artifacts and the CLI (`default`, `4g2r`).
    pub fn key(&self) -> String {
        match self {
            Fleet::DefaultPool => "default".to_string(),
            Fleet::Mixed { gpus, rdus } => format!("{gpus}g{rdus}r"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Fleet::DefaultPool => "default RDU pair (4-tile C++ + 2-tile Python)".to_string(),
            Fleet::Mixed { gpus, rdus } => {
                format!("mixed pool: {gpus}x A100 + {rdus}x RDU tile groups")
            }
        }
    }

    /// Pool members this fleet places behind the fabric.
    pub fn pool_size(&self) -> usize {
        match self {
            Fleet::DefaultPool => 2,
            Fleet::Mixed { gpus, rdus } => *gpus as usize + *rdus as usize,
        }
    }

    /// Parse a CLI key: `default` or `<G>g<R>r` (e.g. `4g2r`).
    pub fn parse(s: &str) -> Option<Fleet> {
        if s == "default" {
            return Some(Fleet::DefaultPool);
        }
        let (g, rest) = s.split_once('g')?;
        let r = rest.strip_suffix('r')?;
        let fleet = Fleet::Mixed { gpus: g.parse().ok()?, rdus: r.parse().ok()? };
        (fleet.pool_size() >= 1).then_some(fleet)
    }
}

/// Which engine a cell runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Closed-form virtual-time cluster (`cluster::Cluster`).
    Analytic,
    /// Discrete-event engine (`eventsim::EventSim`).
    Event,
    /// Coupled timestep model (`eventsim::cogsim::CogSim`).
    Cog,
    /// Steady-state fluid approximation of the coupled model
    /// (`crate::fluid`): microseconds per cell instead of seconds, so
    /// the grid reaches leadership-class rank/pool counts the
    /// event-for-event engines cannot.
    Fluid,
}

impl Kind {
    pub const ALL: [Kind; 4] = [Kind::Analytic, Kind::Event, Kind::Cog, Kind::Fluid];

    /// Stable snake_case key for JSON artifacts and the CLI.
    pub fn key(&self) -> &'static str {
        match self {
            Kind::Analytic => "analytic",
            Kind::Event => "event",
            Kind::Cog => "cog",
            Kind::Fluid => "fluid",
        }
    }

    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "analytic" => Some(Kind::Analytic),
            "event" | "eventsim" => Some(Kind::Event),
            "cog" | "cogsim" => Some(Kind::Cog),
            "fluid" => Some(Kind::Fluid),
            _ => None,
        }
    }
}

/// One control-plane schedule a cell runs under: a timed fleet-event
/// trace plus an optional reactive autoscaler.  The `static` spec
/// (empty trace, no autoscaler) is the legacy behaviour and is
/// byte-identical to never installing a control plane at all — the
/// differential suite in `rust/tests/control_plane_props.rs` pins
/// that.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlSpec {
    /// Stable key for JSON artifacts and the CLI (the parse syntax
    /// round-trips: `static`, `leave:0@40000`, ...).
    pub key: String,
    /// Timed fleet events, as given (engines sort by time via the
    /// event queue).
    pub trace: Vec<FleetEvent>,
    /// Reactive queue-depth autoscaler (cog kind only).
    pub autoscaler: Option<AutoscalerCfg>,
}

impl ControlSpec {
    /// The do-nothing legacy spec.
    pub fn static_() -> ControlSpec {
        ControlSpec { key: "static".to_string(), trace: Vec::new(), autoscaler: None }
    }

    /// True when this spec changes nothing (the differential anchor).
    pub fn is_static(&self) -> bool {
        self.trace.is_empty() && self.autoscaler.is_none()
    }

    /// Parse a CLI control spec: `+`-separated actions, times in µs.
    ///
    /// * `static` — no events (must stand alone)
    /// * `leave:IDX@T` — backend `IDX` leaves at `T` µs
    /// * `join:IDX@T` — backend `IDX` (re)joins at `T` µs
    /// * `degrade:FACTOR@T` — all fabric links scale to `FACTOR`×
    /// * `restore@T` — fabric capacities return to as-built
    /// * `rankfail:R@T` — rank `R` fails and replays its timestep
    /// * `auto:INIT:MIN-MAX:LO:HI` — autoscaler starting at `INIT`
    ///   active backends, clamped to `[MIN, MAX]`, shrinking below
    ///   `LO` µs mean backlog and growing above `HI` µs
    ///
    /// Example: `leave:0@30000+join:0@60000+auto:2:1-4:100:2000`.
    ///
    /// Errors name the offending clause and restate the grammar, so a
    /// CLI caller can surface them verbatim — a malformed user spec
    /// must exit with a named error, never a panic.
    pub fn parse(s: &str) -> Result<ControlSpec, String> {
        let err = |clause: &str, why: &str| {
            format!("bad clause {clause:?}: {why}; grammar: {}", Self::GRAMMAR)
        };
        if s.is_empty() {
            return Err(format!("empty spec; grammar: {}", Self::GRAMMAR));
        }
        if s == "static" {
            return Ok(ControlSpec::static_());
        }
        let mut trace = Vec::new();
        let mut autoscaler = None;
        let mut seen: Vec<&str> = Vec::new();
        for part in s.split('+') {
            if part.is_empty() {
                return Err(err(part, "empty clause (stray '+'?)"));
            }
            if seen.contains(&part) {
                return Err(err(part, "duplicate clause"));
            }
            seen.push(part);
            if part == "static" {
                return Err(err(part, "'static' must stand alone"));
            }
            if let Some(spec) = part.strip_prefix("auto:") {
                if autoscaler.is_some() {
                    return Err(err(part, "at most one auto: clause per spec"));
                }
                // INIT:MIN-MAX:LO:HI
                let fields = (|| {
                    let mut fields = spec.split(':');
                    let initial: usize = fields.next()?.parse().ok()?;
                    let (min_s, max_s) = fields.next()?.split_once('-')?;
                    let low_us: f64 = fields.next()?.parse().ok()?;
                    let high_us: f64 = fields.next()?.parse().ok()?;
                    if fields.next().is_some() {
                        return None;
                    }
                    Some(AutoscalerCfg {
                        initial,
                        min_active: min_s.parse().ok()?,
                        max_active: max_s.parse().ok()?,
                        low_s: low_us * 1e-6,
                        high_s: high_us * 1e-6,
                    })
                })();
                let cfg = match fields {
                    Some(cfg) => cfg,
                    None => return Err(err(part, "want auto:INIT:MIN-MAX:LO:HI")),
                };
                // tier-independent bound checks fail here, at the
                // CLI boundary; the tier-size check happens where the
                // fleet is known (`try_run_cell_ctl`)
                if let Err(why) = cfg.validate(usize::MAX) {
                    return Err(err(part, &why));
                }
                autoscaler = Some(cfg);
                continue;
            }
            let (head, at_us) = match part.split_once('@') {
                Some(x) => x,
                None => return Err(err(part, "missing '@T' event time")),
            };
            let at_us: f64 = match at_us.parse() {
                Ok(v) => v,
                Err(_) => return Err(err(part, "event time is not a number")),
            };
            if !(at_us.is_finite() && at_us >= 0.0) {
                return Err(err(part, "event time must be finite and >= 0 (us)"));
            }
            let action = if head == "restore" {
                FleetAction::LinkRestore
            } else {
                let (verb, arg) = match head.split_once(':') {
                    Some(x) => x,
                    None => return Err(err(part, "unknown verb (or missing ':ARG')")),
                };
                let index = |what: &str, arg: &str| {
                    arg.parse::<usize>()
                        .map_err(|_| err(part, &format!("{what} is not an integer")))
                };
                match verb {
                    "leave" => FleetAction::BackendLeave(index("backend index", arg)?),
                    "join" => FleetAction::BackendJoin(index("backend index", arg)?),
                    "rankfail" => FleetAction::RankFail(index("rank index", arg)?),
                    "degrade" => {
                        let factor: f64 = arg
                            .parse()
                            .map_err(|_| err(part, "degrade factor is not a number"))?;
                        if !(factor > 0.0 && factor.is_finite()) {
                            return Err(err(part, "degrade factor must be finite and > 0"));
                        }
                        FleetAction::LinkDegrade(factor)
                    }
                    _ => return Err(err(part, "unknown verb")),
                }
            };
            trace.push(FleetEvent { at_s: at_us * 1e-6, action });
        }
        Ok(ControlSpec { key: s.to_string(), trace, autoscaler })
    }

    /// The spec grammar, restated in every parse error (and by
    /// `repro help`).
    pub const GRAMMAR: &'static str = "static | leave:IDX@T | join:IDX@T | \
         degrade:FACTOR@T | restore@T | rankfail:R@T | auto:INIT:MIN-MAX:LO:HI, \
         joined with '+', times in us";
}

/// The swept dimensions.  Axes that do not apply to a cell's kind or
/// topology collapse to their first (or canonical) value instead of
/// multiplying the grid.
#[derive(Debug, Clone)]
pub struct Axes {
    /// Workload kinds to run (each kind sweeps the full grid).
    pub kinds: Vec<Kind>,
    pub topologies: Vec<Topology>,
    /// Pool compositions (collapses on the all-local topology).
    pub fleets: Vec<Fleet>,
    pub policies: Vec<Policy>,
    /// MPI rank counts (local topology gets one GPU per rank).
    pub rank_counts: Vec<usize>,
    /// Arrival processes (event kind only; others ignore it).
    pub arrivals: Vec<ArrivalProcess>,
    /// Dynamic-batching windows, µs; `0` disables batching
    /// (event + cog kinds).
    pub windows_us: Vec<f64>,
    /// Target-model counts per rank (cog kind only).
    pub models_per_rank: Vec<usize>,
    /// Residency swap costs, seconds (cog kind only).
    pub swap_costs_s: Vec<f64>,
    /// Compute/inference overlap fractions (cog kind only).
    pub overlaps: Vec<f64>,
    /// Fabric oversubscription factors (collapses to 1:1 on the
    /// all-local topology).
    pub fabric_oversubs: Vec<f64>,
    /// Control-plane schedules (event + cog kinds; the analytic
    /// closed form and the steady-state fluid kind have no clock for
    /// timed events, so the axis collapses there).  Cells reference
    /// these by index
    /// ([`Scenario::control`]) so [`Scenario`] stays `Copy`.
    pub controls: Vec<ControlSpec>,
}

impl Axes {
    /// The control spec a cell references (total: out-of-range —
    /// which only a hand-built [`Scenario`] can produce — is static).
    pub fn control(&self, idx: usize) -> ControlSpec {
        self.controls.get(idx).cloned().unwrap_or_else(ControlSpec::static_)
    }
}

impl Default for Axes {
    fn default() -> Self {
        Axes {
            kinds: vec![Kind::Cog],
            topologies: vec![Topology::Local, Topology::Pooled],
            fleets: vec![Fleet::DefaultPool],
            policies: vec![Policy::RoundRobin, Policy::LatencyAware],
            rank_counts: vec![4, 32],
            arrivals: vec![ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 }],
            windows_us: vec![0.0],
            models_per_rank: vec![8],
            swap_costs_s: vec![0.0],
            overlaps: vec![0.0],
            fabric_oversubs: vec![1.0, 4.0],
            controls: vec![ControlSpec::static_()],
        }
    }
}

/// The scalar workload knobs every cell shares (the union of the
/// three legacy config structs' non-axis fields).
#[derive(Debug, Clone, Copy)]
pub struct Knobs {
    /// Per-material Hermit instances (analytic + event kinds).
    pub materials: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Synchronized event mode: requests per rank per burst.
    pub requests_per_burst: usize,
    /// Cog: in-the-loop requests per rank per timestep (K).
    pub requests_per_step: usize,
    /// Every `mir_every`-th burst/step adds one MIR request per rank.
    pub mir_every: usize,
    pub mir_samples: usize,
    /// Sample cap per coalesced batch.
    pub max_batch: usize,
    /// Event: arrival generators stop here; in-flight work drains.
    pub horizon_s: f64,
    /// Analytic + cog: simulated timesteps.
    pub timesteps: usize,
    /// Cog: physics compute per rank per timestep, seconds.
    pub compute_s: f64,
    /// Cog: models resident per backend (LRU).
    pub residency_slots: usize,
    /// Analytic: Hydra zones per rank per timestep.
    pub zones_per_rank: usize,
    /// Analytic: virtual seconds between timesteps.
    pub step_period_s: f64,
    /// Analytic: base MIR mixed-zone count per rank per timestep.
    pub mir_base_zones: usize,
    /// Workload seed (fixed seed → byte-stable summary).
    pub seed: u64,
}

impl Default for Knobs {
    fn default() -> Self {
        Knobs {
            materials: 8,
            samples_per_request: (2, 3),
            requests_per_burst: 6,
            requests_per_step: 6,
            mir_every: 0,
            mir_samples: 512,
            max_batch: 256,
            horizon_s: 0.2,
            timesteps: 8,
            compute_s: 2e-3,
            residency_slots: 4,
            zones_per_rank: 200,
            step_period_s: 0.02,
            mir_base_zones: 1024,
            seed: 42,
        }
    }
}

/// The declarative scenario grid: axes × workload kind + shared
/// knobs.  `repro scenario` runs one of these; the legacy campaign
/// modes are thin views over it.
#[derive(Debug, Clone, Default)]
pub struct Grid {
    pub axes: Axes,
    pub knobs: Knobs,
}

/// One cell of the expanded grid.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub kind: Kind,
    pub topology: Topology,
    pub fleet: Fleet,
    pub policy: Policy,
    pub ranks: usize,
    /// Event kind only; carried (and emitted) regardless.
    pub arrival: ArrivalProcess,
    /// Batching window, µs; 0 = off (event + cog kinds).
    pub window_us: f64,
    /// Cog kind only: models per rank.
    pub models: usize,
    /// Cog kind only: residency swap cost, seconds.
    pub swap_s: f64,
    /// Cog kind only: compute/inference overlap fraction.
    pub overlap: f64,
    /// Fabric oversubscription (1.0 = non-blocking).
    pub oversub: f64,
    /// Control-plane schedule: index into [`Axes::controls`]
    /// (`0` = the first, `static` by default).
    pub control: usize,
}

impl Scenario {
    /// Compact deterministic cell label, unique within a grid (every
    /// axis is in the key) — used by the `--timings` side-channel and
    /// as the per-cell process name in merged flight-recorder traces.
    pub fn cell_key(&self) -> String {
        format!(
            "{}/{}/{}/{}/r{}/{}/w{}/m{}/s{}/v{}/x{}/c{}",
            self.kind.key(),
            self.topology.key(),
            self.fleet.key(),
            self.policy.key(),
            self.ranks,
            self.arrival.key(),
            self.window_us,
            self.models,
            self.swap_s * 1e6,
            self.overlap,
            self.oversub,
            self.control,
        )
    }
}

/// The oversubscription cells a topology actually sweeps: the
/// configured list where the fabric exists, the single 1:1 cell on
/// the all-local topology.
pub fn oversubs_for(topology: Topology, oversubs: &[f64]) -> Vec<f64> {
    if topology.pays_the_link() {
        oversubs.to_vec()
    } else {
        vec![1.0]
    }
}

/// The fleet cells a topology actually sweeps: the configured pool
/// compositions where a pool exists, the single default cell on the
/// all-local topology (no pool to compose).
pub fn fleets_for(topology: Topology, fleets: &[Fleet]) -> Vec<Fleet> {
    if topology.pays_the_link() {
        fleets.to_vec()
    } else {
        vec![Fleet::DefaultPool]
    }
}

/// An axis a cell's kind cannot observe collapses to its first
/// configured value instead of multiplying the grid with duplicate
/// identical cells (empty axes stay empty: no cells).
fn axis_for<T: Copy>(applies: bool, axis: &[T]) -> Vec<T> {
    if applies || axis.len() <= 1 {
        axis.to_vec()
    } else {
        vec![axis[0]]
    }
}

impl Grid {
    /// Expand the axes into cells.  The nesting order — kind,
    /// topology, fleet, policy, ranks, arrival, window, models, swap,
    /// overlap, oversubscription — reproduces every legacy mode's
    /// sweep order when its unused axes are singletons, which keeps
    /// the committed golden JSON byte-stable.  Axes a kind or
    /// topology cannot observe collapse instead of multiplying:
    /// arrivals are event-only; windows are event+cog; models, swap
    /// costs and overlaps are cog-only; the fleet and
    /// oversubscription axes collapse on the all-local topology.
    pub fn cells(&self) -> Vec<Scenario> {
        let a = &self.axes;
        // the control axis sweeps by index so cells stay Copy; an
        // empty list means the single static schedule (index 0 is
        // static via `Axes::control`'s total lookup)
        let control_ids: Vec<usize> = if a.controls.is_empty() {
            vec![0]
        } else {
            (0..a.controls.len()).collect()
        };
        let mut out = Vec::new();
        for &kind in &a.kinds {
            for &topology in &a.topologies {
                for fleet in fleets_for(topology, &a.fleets) {
                    for &policy in &a.policies {
                        for &ranks in &a.rank_counts {
                            for arrival in axis_for(kind == Kind::Event, &a.arrivals) {
                                for window_us in
                                    axis_for(kind != Kind::Analytic, &a.windows_us)
                                {
                                    for models in axis_for(
                                        matches!(kind, Kind::Cog | Kind::Fluid),
                                        &a.models_per_rank,
                                    ) {
                                        for swap_s in axis_for(
                                            matches!(kind, Kind::Cog | Kind::Fluid),
                                            &a.swap_costs_s,
                                        ) {
                                            for overlap in axis_for(
                                                matches!(kind, Kind::Cog | Kind::Fluid),
                                                &a.overlaps,
                                            ) {
                                                for oversub in
                                                    oversubs_for(topology, &a.fabric_oversubs)
                                                {
                                                    for control in axis_for(
                                                        matches!(kind, Kind::Event | Kind::Cog),
                                                        &control_ids,
                                                    ) {
                                                        out.push(Scenario {
                                                            kind,
                                                            topology,
                                                            fleet,
                                                            policy,
                                                            ranks,
                                                            arrival,
                                                            window_us,
                                                            models,
                                                            swap_s,
                                                            overlap,
                                                            oversub,
                                                            control,
                                                        });
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Human-readable axis table for `repro scenario --list`: every
    /// swept axis with its current values, plus which kinds use it.
    pub fn axis_help(&self) -> Vec<(&'static str, String, &'static str)> {
        let a = &self.axes;
        let join = |v: Vec<String>| v.join(",");
        vec![
            ("kinds", join(a.kinds.iter().map(|k| k.key().to_string()).collect()),
             "workload kind per cell (analytic|event|cog|fluid)"),
            ("topologies", join(a.topologies.iter().map(|t| t.key().to_string()).collect()),
             "coupling topology (local|pooled|hybrid)"),
            ("fleets", join(a.fleets.iter().map(|f| f.key()).collect()),
             "pool composition (default or <G>g<R>r, e.g. 4g2r); collapses on local"),
            ("policies", join(a.policies.iter().map(|p| p.key().to_string()).collect()),
             "routing policy"),
            ("ranks", join(a.rank_counts.iter().map(|r| r.to_string()).collect()),
             "MPI rank counts"),
            ("arrivals", join(a.arrivals.iter().map(|x| x.key().to_string()).collect()),
             "arrival process (event kind)"),
            ("windows-us", join(a.windows_us.iter().map(|w| w.to_string()).collect()),
             "batching window in us, 0 = off (event+cog kinds)"),
            ("models", join(a.models_per_rank.iter().map(|m| m.to_string()).collect()),
             "models per rank (cog+fluid kinds)"),
            ("swaps-us",
             join(a.swap_costs_s.iter().map(|s| (s * 1e6).to_string()).collect()),
             "residency swap cost in us (cog+fluid kinds)"),
            ("overlaps", join(a.overlaps.iter().map(|o| o.to_string()).collect()),
             "compute/inference overlap fraction (cog+fluid kinds)"),
            ("oversubs", join(a.fabric_oversubs.iter().map(|o| o.to_string()).collect()),
             "fabric oversubscription factors; collapses to 1:1 on local"),
            ("controls", join(a.controls.iter().map(|c| c.key.clone()).collect()),
             "control-plane schedule (static, leave:I@T, join:I@T, degrade:F@T, \
              restore@T, rankfail:R@T, auto:INIT:MIN-MAX:LO:HI; + to combine; \
              T in us); event+cog kinds"),
        ]
    }
}

// ----------------------------------------------------------- fleets

/// Tiering: which backend indices serve which model class.
pub struct Tiering {
    pub hermit: Vec<usize>,
    pub mir: Vec<usize>,
}

fn local_gpu(r: usize) -> Box<dyn Backend> {
    Box::new(GpuBackend::node_local(format!("gpu/rank{r}"), Gpu::a100(), Api::TrtCudaGraphs))
}

/// The pool members a fleet places behind the shared link.  The
/// default pool is deliberately heterogeneous — a full 4-tile group
/// on the optimised C++ stack next to a half-provisioned 2-tile group
/// still on the naive Python stack: state-blind policies pay for not
/// seeing the difference.  Mixed fleets extend the same idea across
/// architectures: pooled A100s (remote, over the same link) next to
/// RDU tile groups alternating the default pair's shapes.
fn pool_members(fleet: Fleet, pool_link: &Link) -> Vec<Box<dyn Backend>> {
    match fleet {
        Fleet::DefaultPool => vec![
            Box::new(RduBackend::with_link(
                "rdu/pool0",
                4,
                RduApi::CppOptimized,
                pool_link.clone(),
            )),
            Box::new(RduBackend::with_link("rdu/pool1", 2, RduApi::Python, pool_link.clone())),
        ],
        Fleet::Mixed { gpus, rdus } => {
            assert!(gpus as usize + rdus as usize >= 1, "mixed fleet needs members");
            let mut members: Vec<Box<dyn Backend>> = Vec::new();
            for i in 0..gpus as usize {
                members.push(Box::new(GpuBackend::remote(
                    format!("gpu/pool{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                    pool_link.clone(),
                )));
            }
            for j in 0..rdus as usize {
                let (tiles, api) = if j % 2 == 0 {
                    (4, RduApi::CppOptimized)
                } else {
                    (2, RduApi::Python)
                };
                members.push(Box::new(RduBackend::with_link(
                    format!("rdu/pool{}", gpus as usize + j),
                    tiles,
                    api,
                    pool_link.clone(),
                )));
            }
            members
        }
    }
}

/// Build a topology's backend fleet + tiering (shared by all three
/// workload kinds).
pub fn build_fleet(
    topology: Topology,
    ranks: usize,
    fleet: Fleet,
    pool_link: &Link,
) -> (Vec<Box<dyn Backend>>, Tiering) {
    match topology {
        Topology::Local => {
            let backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let all: Vec<usize> = (0..backends.len()).collect();
            (backends, Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Pooled => {
            let backends = pool_members(fleet, pool_link);
            let all: Vec<usize> = (0..backends.len()).collect();
            (backends, Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Hybrid => {
            let mut backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let gpu_idx: Vec<usize> = (0..backends.len()).collect();
            backends.extend(pool_members(fleet, pool_link));
            let pool_idx: Vec<usize> = (gpu_idx.len()..backends.len()).collect();
            (backends, Tiering { hermit: pool_idx, mir: gpu_idx })
        }
    }
}

/// Fabric spec for an event/cog cell: the flow-level topology plus
/// the backend→accel endpoint map matching [`build_fleet`]'s layout.
/// `None` on the all-local topology (no shared links to model).
pub fn build_fabric_spec(
    topology: Topology,
    ranks: usize,
    fleet: Fleet,
    oversub: f64,
) -> Option<FabricSpec> {
    let pool = fleet.pool_size();
    match topology {
        Topology::Local => None,
        Topology::Pooled => Some(FabricSpec {
            topology: NetTopology::pooled(ranks, pool, oversub),
            accel_of_backend: (0..pool).collect(),
        }),
        Topology::Hybrid => Some(FabricSpec {
            topology: NetTopology::hybrid(ranks, pool, oversub),
            // GPU i sits in node i; the pool rides the fabric.
            accel_of_backend: (0..ranks).chain(ranks..ranks + pool).collect(),
        }),
    }
}

/// Campaign model mapping: Hermit requests use the Hermit profile;
/// MIR requests use the Fig-20 no-layernorm variant so GPU and RDU
/// backends execute the same network.
pub(crate) fn profile_for(model: &str) -> ModelProfile {
    if model.starts_with("mir") {
        profiles::mir_noln()
    } else {
        profiles::hermit()
    }
}

// ---------------------------------------------- legacy config views

/// Analytic-campaign knobs (defaults sized so the full 3×4 sweep runs
/// in milliseconds of wall time).
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Hydra zones per rank per timestep.
    pub zones_per_rank: usize,
    /// Per-material Hermit instances per rank.
    pub materials: usize,
    /// Simulated physics timesteps.
    pub timesteps: usize,
    /// Virtual seconds between timesteps (queues drain in between).
    pub step_period_s: f64,
    /// Base MIR mixed-zone count per rank per timestep.
    pub mir_base_zones: usize,
    /// Fabric oversubscription factors to sweep on topologies with
    /// pooled backends (the analytic mode applies the closed-form
    /// worst-case derate: pool link bandwidth ÷ oversubscription).
    pub fabric_oversubs: Vec<f64>,
    /// Workload seed (fixed seed → byte-stable summary).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            ranks: 4,
            zones_per_rank: 200,
            materials: 8,
            timesteps: 12,
            step_period_s: 0.02,
            mir_base_zones: 1024,
            fabric_oversubs: vec![1.0],
            seed: 42,
        }
    }
}

impl CampaignConfig {
    /// The equivalent declarative grid (analytic kind, full topology
    /// × policy cross, one cell per oversubscription).
    pub fn grid(&self) -> Grid {
        Grid {
            axes: Axes {
                kinds: vec![Kind::Analytic],
                topologies: Topology::ALL.to_vec(),
                fleets: vec![Fleet::DefaultPool],
                policies: Policy::ALL.to_vec(),
                rank_counts: vec![self.ranks],
                arrivals: vec![ArrivalProcess::Synchronized {
                    period_s: self.step_period_s,
                    jitter_s: 0.0,
                }],
                windows_us: vec![0.0],
                models_per_rank: vec![self.materials],
                swap_costs_s: vec![0.0],
                overlaps: vec![0.0],
                fabric_oversubs: self.fabric_oversubs.clone(),
                controls: vec![ControlSpec::static_()],
            },
            knobs: Knobs {
                materials: self.materials,
                timesteps: self.timesteps,
                zones_per_rank: self.zones_per_rank,
                step_period_s: self.step_period_s,
                mir_base_zones: self.mir_base_zones,
                seed: self.seed,
                ..Knobs::default()
            },
        }
    }
}

/// Event-mode campaign knobs: the discrete-event simulator swept over
/// topology × policy × rank count × arrival process × batching
/// window.  Unlike the analytic sweep, this resolves *when* requests
/// collide — the queueing behaviour of bursty multi-rank arrivals
/// that the closed-form cluster cannot express.
#[derive(Debug, Clone)]
pub struct EventCampaignConfig {
    pub topologies: Vec<Topology>,
    pub policies: Vec<Policy>,
    /// MPI rank counts to sweep (local topology gets one GPU per rank).
    pub rank_counts: Vec<usize>,
    pub arrivals: Vec<ArrivalProcess>,
    /// Dynamic-batching windows, µs; `0` disables batching.
    pub windows_us: Vec<f64>,
    /// Sample cap per coalesced batch.
    pub max_batch: usize,
    /// Per-material Hermit instances.
    pub materials: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Synchronized mode: requests per rank per burst.
    pub requests_per_burst: usize,
    /// Synchronized mode: emit one MIR request per rank every k-th
    /// burst (0 = hermit-only).
    pub mir_every: usize,
    pub mir_samples: usize,
    /// Fabric oversubscription factors to sweep; pooled/hybrid cells
    /// route remote dispatches through the flow-level
    /// [`crate::fabric`] simulator at each factor.
    pub fabric_oversubs: Vec<f64>,
    /// Arrival generators stop here; in-flight work drains.
    pub horizon_s: f64,
    pub seed: u64,
}

impl Default for EventCampaignConfig {
    fn default() -> Self {
        EventCampaignConfig {
            // Hybrid needs MIR traffic to differ from Pooled; the
            // default event sweep studies the bursty in-the-loop
            // Hermit regime, so it covers the two endpoints.
            topologies: vec![Topology::Local, Topology::Pooled],
            policies: vec![Policy::RoundRobin, Policy::LatencyAware],
            rank_counts: vec![4, 64],
            arrivals: vec![
                ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
                ArrivalProcess::Poisson { rate_per_rank: 800.0 },
                ArrivalProcess::ClosedLoop { think_s: 2e-3 },
            ],
            windows_us: vec![0.0, 200.0],
            max_batch: 256,
            materials: 8,
            samples_per_request: (2, 3),
            requests_per_burst: 6,
            mir_every: 0,
            mir_samples: 512,
            fabric_oversubs: vec![1.0, 4.0],
            horizon_s: 0.2,
            seed: 42,
        }
    }
}

impl EventCampaignConfig {
    /// The equivalent declarative grid (event kind).
    pub fn grid(&self) -> Grid {
        Grid {
            axes: Axes {
                kinds: vec![Kind::Event],
                topologies: self.topologies.clone(),
                fleets: vec![Fleet::DefaultPool],
                policies: self.policies.clone(),
                rank_counts: self.rank_counts.clone(),
                arrivals: self.arrivals.clone(),
                windows_us: self.windows_us.clone(),
                models_per_rank: vec![self.materials],
                swap_costs_s: vec![0.0],
                overlaps: vec![0.0],
                fabric_oversubs: self.fabric_oversubs.clone(),
                controls: vec![ControlSpec::static_()],
            },
            knobs: Knobs {
                materials: self.materials,
                samples_per_request: self.samples_per_request,
                requests_per_burst: self.requests_per_burst,
                mir_every: self.mir_every,
                mir_samples: self.mir_samples,
                max_batch: self.max_batch,
                horizon_s: self.horizon_s,
                seed: self.seed,
                ..Knobs::default()
            },
        }
    }
}

/// Coupled-campaign knobs: the CogSim application model swept over
/// topology × policy × rank count × models-per-rank × swap cost ×
/// overlap.  This is the only mode that reports the paper's real
/// figure of merit — time-to-solution — because it is the only one
/// where inference latency feeds back into when the next timestep's
/// requests exist.
#[derive(Debug, Clone)]
pub struct CogCampaignConfig {
    pub topologies: Vec<Topology>,
    pub policies: Vec<Policy>,
    /// MPI rank counts (local topology gets one GPU per rank).
    pub rank_counts: Vec<usize>,
    /// Target-model counts per rank (M per-material Hermit instances).
    pub models_per_rank: Vec<usize>,
    /// Residency swap costs to sweep, seconds.
    pub swap_costs_s: Vec<f64>,
    /// Compute/inference overlap fractions to sweep.
    pub overlaps: Vec<f64>,
    /// Bulk-synchronous timesteps per run.
    pub timesteps: usize,
    /// Physics compute per rank per timestep, seconds.
    pub compute_s: f64,
    /// In-the-loop requests per rank per timestep (K).
    pub requests_per_step: usize,
    /// Samples per request, uniform inclusive.
    pub samples_per_request: (usize, usize),
    /// Every `mir_every`-th step adds one MIR request per rank.
    pub mir_every: usize,
    pub mir_samples: usize,
    /// Models resident per backend (LRU).
    pub residency_slots: usize,
    /// Router batching window, µs; 0 disables batching.
    pub window_us: f64,
    pub max_batch: usize,
    /// Fabric oversubscription factors to sweep; pooled/hybrid cells
    /// route remote dispatches (and residency-swap weight transfers)
    /// through the flow-level [`crate::fabric`] simulator.
    pub fabric_oversubs: Vec<f64>,
    pub seed: u64,
}

impl Default for CogCampaignConfig {
    fn default() -> Self {
        CogCampaignConfig {
            // The two coupling endpoints; hybrid needs MIR cadence
            // (set mir_every > 0) to differ from pooled.
            topologies: vec![Topology::Local, Topology::Pooled],
            policies: Policy::ALL.to_vec(),
            // 4 ranks: the pool's home turf; 32: the burst regime
            // where sharing 2 accelerators (and their fabric) hurts
            rank_counts: vec![4, 32],
            models_per_rank: vec![8],
            // free swaps vs swaps far above the small-batch service
            // time — the regime where affinity routing must win
            swap_costs_s: vec![0.0, 2e-3],
            overlaps: vec![0.0],
            timesteps: 8,
            compute_s: 2e-3,
            requests_per_step: 6,
            samples_per_request: (2, 3),
            mir_every: 0,
            mir_samples: 512,
            residency_slots: 4,
            window_us: 0.0,
            max_batch: 256,
            // the contention axis of the acceptance headline: 1:1
            // non-blocking through 8:1 starved
            fabric_oversubs: vec![1.0, 2.0, 4.0, 8.0],
            seed: 42,
        }
    }
}

impl CogCampaignConfig {
    /// The equivalent declarative grid (cog kind).
    pub fn grid(&self) -> Grid {
        Grid {
            axes: Axes {
                kinds: vec![Kind::Cog],
                topologies: self.topologies.clone(),
                fleets: vec![Fleet::DefaultPool],
                policies: self.policies.clone(),
                rank_counts: self.rank_counts.clone(),
                arrivals: vec![ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 }],
                windows_us: vec![self.window_us],
                models_per_rank: self.models_per_rank.clone(),
                swap_costs_s: self.swap_costs_s.clone(),
                overlaps: self.overlaps.clone(),
                fabric_oversubs: self.fabric_oversubs.clone(),
                controls: vec![ControlSpec::static_()],
            },
            knobs: Knobs {
                samples_per_request: self.samples_per_request,
                requests_per_step: self.requests_per_step,
                mir_every: self.mir_every,
                mir_samples: self.mir_samples,
                max_batch: self.max_batch,
                timesteps: self.timesteps,
                compute_s: self.compute_s,
                residency_slots: self.residency_slots,
                seed: self.seed,
                ..Knobs::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_keys_round_trip() {
        assert_eq!(Fleet::DefaultPool.key(), "default");
        assert_eq!(Fleet::Mixed { gpus: 4, rdus: 2 }.key(), "4g2r");
        assert_eq!(Fleet::parse("default"), Some(Fleet::DefaultPool));
        assert_eq!(Fleet::parse("4g2r"), Some(Fleet::Mixed { gpus: 4, rdus: 2 }));
        assert_eq!(Fleet::parse("0g6r"), Some(Fleet::Mixed { gpus: 0, rdus: 6 }));
        assert_eq!(Fleet::parse("0g0r"), None, "empty pool rejected");
        assert_eq!(Fleet::parse("bogus"), None);
        assert_eq!(Fleet::Mixed { gpus: 4, rdus: 2 }.pool_size(), 6);
    }

    #[test]
    fn control_spec_parses_every_verb() {
        let st = ControlSpec::parse("static").unwrap();
        assert!(st.is_static());
        assert_eq!(st, ControlSpec::static_());

        let c = ControlSpec::parse("leave:0@30000+join:0@60000").unwrap();
        assert_eq!(c.key, "leave:0@30000+join:0@60000");
        assert_eq!(c.trace.len(), 2);
        assert_eq!(c.trace[0].action, FleetAction::BackendLeave(0));
        assert!((c.trace[0].at_s - 30e-3).abs() < 1e-12);
        assert_eq!(c.trace[1].action, FleetAction::BackendJoin(0));
        assert!(c.autoscaler.is_none() && !c.is_static());

        let c = ControlSpec::parse("degrade:0.25@20000+restore@60000").unwrap();
        assert_eq!(c.trace[0].action, FleetAction::LinkDegrade(0.25));
        assert_eq!(c.trace[1].action, FleetAction::LinkRestore);

        let c = ControlSpec::parse("rankfail:3@40000").unwrap();
        assert_eq!(c.trace[0].action, FleetAction::RankFail(3));

        let c = ControlSpec::parse("auto:2:1-4:100:2000").unwrap();
        assert!(c.trace.is_empty());
        let a = c.autoscaler.unwrap();
        assert_eq!((a.initial, a.min_active, a.max_active), (2, 1, 4));
        assert!((a.low_s - 100e-6).abs() < 1e-15 && (a.high_s - 2e-3).abs() < 1e-12);

        // combined trace + autoscaler
        let c = ControlSpec::parse("leave:1@5000+auto:2:1-4:100:2000").unwrap();
        assert_eq!(c.trace.len(), 1);
        assert!(c.autoscaler.is_some());

        for bad in [
            "", "bogus", "leave:0", "leave@30000", "degrade:0@1000", "degrade:-1@1000",
            "restore:1@1000", "leave:0@-5", "auto:2:1-4:100", "auto:2:1-4:100:2000+auto:1:1-2:1:2",
            // hardening pass: stray '+', duplicate clauses, 'static'
            // in a combination, out-of-range autoscaler bounds
            "leave:0@5000+", "+leave:0@5000", "leave:0@5000+leave:0@5000",
            "static+leave:0@5000", "leave:0@5000+static", "auto:5:1-4:100:2000",
            "auto:2:0-4:100:2000", "auto:2:1-4:2000:100", "leave:0@nan",
        ] {
            assert!(ControlSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // errors are user-facing: they name the clause and the grammar
        let e = ControlSpec::parse("frob:1@5000").unwrap_err();
        assert!(e.contains("\"frob:1@5000\"") && e.contains("grammar"), "{e}");
        let e = ControlSpec::parse("leave:0@5000+leave:0@5000").unwrap_err();
        assert!(e.contains("duplicate clause"), "{e}");
        let e = ControlSpec::parse("auto:5:1-4:100:2000").unwrap_err();
        assert!(e.contains("min <= initial <= max"), "{e}");
    }

    #[test]
    fn control_axis_multiplies_event_and_cog_but_not_analytic() {
        let grid = |kind: Kind| Grid {
            axes: Axes {
                kinds: vec![kind],
                topologies: vec![Topology::Pooled],
                policies: vec![Policy::RoundRobin],
                rank_counts: vec![4],
                fabric_oversubs: vec![1.0],
                controls: vec![
                    ControlSpec::static_(),
                    ControlSpec::parse("leave:0@30000").unwrap(),
                ],
                ..Axes::default()
            },
            knobs: Knobs::default(),
        };
        assert_eq!(grid(Kind::Event).cells().len(), 2);
        assert_eq!(grid(Kind::Cog).cells().len(), 2);
        assert_eq!(grid(Kind::Cog).cells()[1].control, 1);
        assert_eq!(grid(Kind::Analytic).cells().len(), 1, "no clock, no control axis");
        // the index lookup is total
        assert_eq!(grid(Kind::Cog).axes.control(7), ControlSpec::static_());
    }

    #[test]
    fn grid_expansion_matches_legacy_event_order() {
        // The generic nesting must reproduce the event mode's legacy
        // loop order: topology → policy → ranks → arrival → window →
        // oversub, with the fleet axis collapsed.
        let cfg = EventCampaignConfig::default();
        let cells = cfg.grid().cells();
        let mut expect = Vec::new();
        for &topology in &cfg.topologies {
            for &policy in &cfg.policies {
                for &ranks in &cfg.rank_counts {
                    for &arrival in &cfg.arrivals {
                        for &window_us in &cfg.windows_us {
                            for oversub in oversubs_for(topology, &cfg.fabric_oversubs) {
                                expect.push((topology, policy, ranks, arrival, window_us, oversub));
                            }
                        }
                    }
                }
            }
        }
        assert_eq!(cells.len(), expect.len());
        for (cell, (topology, policy, ranks, arrival, window_us, oversub)) in
            cells.iter().zip(expect)
        {
            assert_eq!(cell.kind, Kind::Event);
            assert_eq!(cell.topology, topology);
            assert_eq!(cell.fleet, Fleet::DefaultPool);
            assert_eq!(cell.policy, policy);
            assert_eq!(cell.ranks, ranks);
            assert_eq!(cell.arrival, arrival);
            assert_eq!(cell.window_us, window_us);
            assert_eq!(cell.oversub, oversub);
        }
    }

    #[test]
    fn grid_expansion_matches_legacy_cog_order() {
        let cfg = CogCampaignConfig::default();
        let cells = cfg.grid().cells();
        let mut expect = Vec::new();
        for &topology in &cfg.topologies {
            for &policy in &cfg.policies {
                for &ranks in &cfg.rank_counts {
                    for &models in &cfg.models_per_rank {
                        for &swap_s in &cfg.swap_costs_s {
                            for &overlap in &cfg.overlaps {
                                for oversub in oversubs_for(topology, &cfg.fabric_oversubs) {
                                    expect.push((topology, policy, ranks, models, swap_s, overlap,
                                                 oversub));
                                }
                            }
                        }
                    }
                }
            }
        }
        assert_eq!(cells.len(), expect.len());
        for (cell, (topology, policy, ranks, models, swap_s, overlap, oversub)) in
            cells.iter().zip(expect)
        {
            assert_eq!(cell.kind, Kind::Cog);
            assert_eq!((cell.topology, cell.policy, cell.ranks), (topology, policy, ranks));
            assert_eq!((cell.models, cell.swap_s, cell.overlap), (models, swap_s, overlap));
            assert_eq!(cell.oversub, oversub);
        }
    }

    #[test]
    fn kind_inapplicable_axes_collapse_instead_of_multiplying() {
        // A cog grid with three arrival processes and an event grid
        // with three swap costs would otherwise re-run identical
        // cells; only the axes the kind can observe multiply.
        let grid = |kind: Kind| Grid {
            axes: Axes {
                kinds: vec![kind],
                topologies: vec![Topology::Pooled],
                policies: vec![Policy::RoundRobin],
                rank_counts: vec![4],
                arrivals: vec![
                    ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
                    ArrivalProcess::Poisson { rate_per_rank: 800.0 },
                    ArrivalProcess::ClosedLoop { think_s: 2e-3 },
                ],
                swap_costs_s: vec![0.0, 1e-3, 2e-3],
                fabric_oversubs: vec![1.0],
                ..Axes::default()
            },
            knobs: Knobs::default(),
        };
        // cog: the arrival axis collapses, the swap axis multiplies
        assert_eq!(grid(Kind::Cog).cells().len(), 3);
        assert!(grid(Kind::Cog).cells().iter().all(|c| c.arrival.key() == "synchronized"));
        // event: the swap axis collapses, the arrival axis multiplies
        assert_eq!(grid(Kind::Event).cells().len(), 3);
        assert!(grid(Kind::Event).cells().iter().all(|c| c.swap_s == 0.0));
        // analytic: both collapse
        assert_eq!(grid(Kind::Analytic).cells().len(), 1);
    }

    #[test]
    fn local_topology_collapses_fleet_and_oversub_axes() {
        let grid = Grid {
            axes: Axes {
                kinds: vec![Kind::Cog],
                topologies: vec![Topology::Local, Topology::Pooled],
                fleets: vec![Fleet::DefaultPool, Fleet::Mixed { gpus: 4, rdus: 2 }],
                policies: vec![Policy::RoundRobin],
                rank_counts: vec![4],
                fabric_oversubs: vec![1.0, 8.0],
                ..Axes::default()
            },
            knobs: Knobs::default(),
        };
        let cells = grid.cells();
        let local: Vec<_> =
            cells.iter().filter(|c| c.topology == Topology::Local).collect();
        let pooled: Vec<_> =
            cells.iter().filter(|c| c.topology == Topology::Pooled).collect();
        assert_eq!(local.len(), 1, "local: both axes collapse");
        assert_eq!(pooled.len(), 4, "pooled: 2 fleets x 2 oversubs");
    }

    #[test]
    fn mixed_fleet_builds_pool_members_for_every_topology() {
        let link = Link::infiniband_cx6();
        let fleet = Fleet::Mixed { gpus: 4, rdus: 2 };
        let (pool, tier) = build_fleet(Topology::Pooled, 8, fleet, &link);
        assert_eq!(pool.len(), 6);
        assert_eq!(tier.hermit, (0..6).collect::<Vec<_>>());
        assert!(pool[0].name().starts_with("gpu/pool"));
        assert!(pool[4].name().starts_with("rdu/pool"));
        // pooled GPUs pay the link like any pool member
        let p = profiles::hermit();
        assert!(pool[0].link_overhead_s(&p, 4) > 0.0);

        let (hybrid, tier) = build_fleet(Topology::Hybrid, 3, fleet, &link);
        assert_eq!(hybrid.len(), 3 + 6);
        assert_eq!(tier.mir, vec![0, 1, 2], "MIR stays on the local GPUs");
        assert_eq!(tier.hermit, (3..9).collect::<Vec<_>>());

        // the fabric spec tracks the pool size
        let spec = build_fabric_spec(Topology::Pooled, 8, fleet, 2.0).unwrap();
        assert_eq!(spec.topology.accels(), 6);
        spec.validate(6);
        let spec = build_fabric_spec(Topology::Hybrid, 3, fleet, 2.0).unwrap();
        assert_eq!(spec.topology.accels(), 3 + 6);
        spec.validate(9);
        assert!(build_fabric_spec(Topology::Local, 8, fleet, 2.0).is_none());
    }

    #[test]
    fn mixed_zero_gpu_pair_matches_default_pool_shape() {
        // Mixed{0g2r} is exactly the legacy default pool: same names,
        // same tile shapes, same link — the fleet axis is anchored.
        let link = Link::infiniband_cx6();
        let (a, _) = build_fleet(Topology::Pooled, 4, Fleet::DefaultPool, &link);
        let (b, _) = build_fleet(Topology::Pooled, 4, Fleet::Mixed { gpus: 0, rdus: 2 }, &link);
        assert_eq!(a.len(), b.len());
        let p = profiles::hermit();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name(), y.name());
            assert_eq!(x.execute_s(&p, 64), y.execute_s(&p, 64));
            assert_eq!(x.link_overhead_s(&p, 64), y.link_overhead_s(&p, 64));
        }
    }
}
