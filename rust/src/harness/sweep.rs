//! The one sweep engine: expand a [`Grid`] into cells, run each cell
//! on its engine, collect results.
//!
//! Every campaign mode — analytic, event, coupled — used to carry its
//! own nested sweep loops and cell runner; this module holds the
//! single copy ([`run_grid`] / [`run_cell`]) and re-derives the three
//! legacy entry points ([`run_campaign`], [`run_event_campaign`],
//! [`run_cog_campaign`] and their per-cell helpers) as thin wrappers,
//! so the committed goldens and every existing caller keep working
//! byte-for-byte.

use std::time::Instant;

use crate::cluster::{BackendReport, Cluster, Policy};
use crate::eventsim::{
    ArrivalProcess, Batching, CogSim, CogSimConfig, CogSummary, EventSim, EventSimConfig,
    EventSummary,
};
use crate::fluid::{self, FluidSummary};
use crate::netsim::Link;
use crate::trace::Recorder;
use crate::util::stats;
use crate::workload::{HydraWorkload, MirWorkload};

use super::scenario::{
    build_fabric_spec, build_fleet, profile_for, CampaignConfig, CogCampaignConfig,
    ControlSpec, EventCampaignConfig, Fleet, Grid, Kind, Knobs, Scenario, Topology,
};

// ------------------------------------------------------ cell results

/// Latency/throughput summary for one workload within an analytic
/// cell.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSummary {
    pub requests: u64,
    pub samples: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_link_overhead_s: f64,
    /// Samples over the scenario makespan.
    pub samples_per_s: f64,
}

impl WorkloadSummary {
    fn from_run(latencies: &[f64], link_overheads: &[f64], samples: u64, makespan_s: f64) -> Self {
        WorkloadSummary {
            requests: latencies.len() as u64,
            samples,
            mean_s: stats::mean(latencies),
            p50_s: stats::percentile(latencies, 50.0),
            p95_s: stats::percentile(latencies, 95.0),
            p99_s: stats::percentile(latencies, 99.0),
            mean_link_overhead_s: stats::mean(link_overheads),
            samples_per_s: if makespan_s > 0.0 { samples as f64 / makespan_s } else { 0.0 },
        }
    }
}

/// The analytic kind's per-cell payload.
#[derive(Debug, Clone)]
pub struct AnalyticSummary {
    pub hydra: WorkloadSummary,
    pub mir: WorkloadSummary,
    pub makespan_s: f64,
    pub backends: Vec<BackendReport>,
}

/// One cell's result payload, by workload kind.
#[derive(Debug, Clone)]
pub enum CellSummary {
    Analytic(AnalyticSummary),
    Event(EventSummary),
    Cog(CogSummary),
    Fluid(FluidSummary),
}

/// One executed grid cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub scenario: Scenario,
    pub summary: CellSummary,
}

impl CellResult {
    /// The event summary, if this cell ran the event kind.
    pub fn event(&self) -> Option<&EventSummary> {
        match &self.summary {
            CellSummary::Event(s) => Some(s),
            _ => None,
        }
    }

    /// The cog summary, if this cell ran the coupled kind.
    pub fn cog(&self) -> Option<&CogSummary> {
        match &self.summary {
            CellSummary::Cog(s) => Some(s),
            _ => None,
        }
    }

    /// The analytic summary, if this cell ran the analytic kind.
    pub fn analytic(&self) -> Option<&AnalyticSummary> {
        match &self.summary {
            CellSummary::Analytic(s) => Some(s),
            _ => None,
        }
    }

    /// The fluid summary, if this cell ran the fluid kind.
    pub fn fluid(&self) -> Option<&FluidSummary> {
        match &self.summary {
            CellSummary::Fluid(s) => Some(s),
            _ => None,
        }
    }
}

/// Wall-clock and event-volume side-channel for one executed cell.
/// Wall time is the only place real time is allowed to appear — it
/// never enters a golden-pinned summary, only the separate
/// `--timings` output.
#[derive(Debug, Clone)]
pub struct CellTiming {
    pub wall_ms: f64,
    /// Events popped by the engine (`0` for the analytic and fluid
    /// kinds, which have no event loop).
    pub events: u64,
    pub events_per_s: f64,
}

/// One executed cell plus its side-channels: the deterministic result
/// (exactly what [`run_cell_ctl`] returns), the wall-clock timing,
/// and — when the flight recorder was armed and the kind is
/// engine-backed — the detached [`Recorder`].
#[derive(Debug)]
pub struct CellRun {
    pub result: CellResult,
    pub timing: CellTiming,
    pub recorder: Option<Box<Recorder>>,
    /// The engine's always-on per-device busy integral (seconds of
    /// service) — the recorder's reconciliation ground truth; empty
    /// for the analytic and fluid kinds.
    pub device_busy_s: Vec<f64>,
}

/// An executed grid: the configuration plus every cell's result, in
/// expansion order.
#[derive(Debug, Clone)]
pub struct GridResult {
    pub grid: Grid,
    pub cells: Vec<CellResult>,
}

impl GridResult {
    /// First cell matching a predicate (cells are in expansion order).
    pub fn find(&self, pred: impl Fn(&Scenario) -> bool) -> Option<&CellResult> {
        self.cells.iter().find(|c| pred(&c.scenario))
    }
}

// ------------------------------------------------------ cell runners

/// Worst-case closed-form fabric derate for the analytic mode: every
/// remote request is assumed to find the oversubscribed uplink fully
/// contended, i.e. the pool link's effective bandwidth divides by the
/// oversubscription factor.  (The event/cog kinds model the real
/// time-varying sharing through [`crate::fabric`].)
fn derated_link(link: &Link, oversub: f64) -> Link {
    assert!(oversub >= 1.0 && oversub.is_finite());
    let mut l = link.clone();
    if l.eff_bandwidth.is_finite() {
        l.eff_bandwidth = l.eff_bandwidth / oversub;
    }
    l
}

/// Run one analytic cell body with an explicit pool link (the link
/// ablation behind the Fig-15/16 anchor test).
fn run_analytic(
    topology: Topology,
    fleet: Fleet,
    policy: Policy,
    ranks: usize,
    knobs: &Knobs,
    pool_link: &Link,
) -> AnalyticSummary {
    let (backends, tier) = build_fleet(topology, ranks, fleet, pool_link);
    let mut cluster = Cluster::new(backends, policy);

    let hydra = HydraWorkload {
        ranks,
        zones_per_rank: knobs.zones_per_rank,
        materials: knobs.materials,
        inferences_per_zone: knobs.samples_per_request,
        seed: knobs.seed,
    };
    let mir = MirWorkload {
        ranks,
        base_zones: knobs.mir_base_zones,
        variation: 0.4,
        seed: knobs.seed ^ 0x5EED,
    };
    let hermit_profile = profile_for("hermit");
    let mir_profile = profile_for("mir");

    let mut hydra_lat = Vec::new();
    let mut hydra_link = Vec::new();
    let mut hydra_samples = 0u64;
    let mut mir_lat = Vec::new();
    let mut mir_link = Vec::new();
    let mut mir_samples = 0u64;

    for t in 0..knobs.timesteps {
        cluster.advance_to(t as f64 * knobs.step_period_s);
        for req in hydra.timestep(t) {
            let routed =
                cluster.submit_among(&tier.hermit, &req.model, &hermit_profile, req.samples);
            hydra_lat.push(routed.latency_s);
            hydra_link.push(routed.link_overhead_s);
            hydra_samples += req.samples as u64;
        }
        for req in mir.timestep(t) {
            let routed = cluster.submit_among(&tier.mir, &req.model, &mir_profile, req.samples);
            mir_lat.push(routed.latency_s);
            mir_link.push(routed.link_overhead_s);
            mir_samples += req.samples as u64;
        }
    }

    let makespan_s = cluster.makespan_s();
    AnalyticSummary {
        hydra: WorkloadSummary::from_run(&hydra_lat, &hydra_link, hydra_samples, makespan_s),
        mir: WorkloadSummary::from_run(&mir_lat, &mir_link, mir_samples, makespan_s),
        makespan_s,
        backends: cluster.report(),
    }
}

/// Run one grid cell on its kind's engine under the static (legacy)
/// control plane.
pub fn run_cell(sc: &Scenario, knobs: &Knobs) -> CellResult {
    run_cell_ctl(sc, knobs, &ControlSpec::static_())
}

/// Run one grid cell on its kind's engine under an explicit
/// control-plane schedule.  A static spec takes the exact legacy
/// code path (no control hooks installed), which is what keeps the
/// committed goldens byte-identical.
///
/// Panics when the control spec is invalid for this cell (e.g. the
/// autoscaler bounds exceed the hermit tier) — programmatic callers
/// own their specs; user-supplied specs go through
/// [`try_run_cell_ctl`], which surfaces the violation as an error.
pub fn run_cell_ctl(sc: &Scenario, knobs: &Knobs, ctl: &ControlSpec) -> CellResult {
    match try_run_cell_ctl(sc, knobs, ctl) {
        Ok(cell) => cell,
        Err(why) => panic!("{why}"),
    }
}

/// Validate a control spec against one cell without running it: an
/// autoscaler whose bounds don't fit the cell's hermit tier is a user
/// error (the spec parses fine in isolation — only the cell knows the
/// tier size), so the CLI boundary pre-flights the whole grid with
/// this and reports a named error instead of aborting mid-sweep.
pub fn validate_cell_ctl(sc: &Scenario, ctl: &ControlSpec) -> Result<(), String> {
    if sc.kind == Kind::Cog {
        if let Some(auto) = &ctl.autoscaler {
            let tier = match sc.topology {
                Topology::Local => sc.ranks,
                Topology::Pooled | Topology::Hybrid => sc.fleet.pool_size(),
            };
            auto.validate(tier).map_err(|why| {
                format!(
                    "control spec {:?} on the {} topology at {} ranks: {why}",
                    ctl.key,
                    sc.topology.key(),
                    sc.ranks
                )
            })?;
        }
    }
    Ok(())
}

/// [`run_cell_ctl`] with the [`validate_cell_ctl`] check surfaced as
/// a `Result` instead of a panic.
pub fn try_run_cell_ctl(
    sc: &Scenario,
    knobs: &Knobs,
    ctl: &ControlSpec,
) -> Result<CellResult, String> {
    Ok(try_run_cell_full(sc, knobs, ctl, false)?.result)
}

/// [`try_run_cell_ctl`] plus the side-channels: wall-clock timing
/// always, and — when `armed` and the cell's kind is engine-backed
/// (event or cog) — the detached flight recorder.  The recorder only
/// observes; with `armed = false` this is the exact legacy cell body,
/// which is what keeps the committed goldens byte-identical.
pub fn try_run_cell_full(
    sc: &Scenario,
    knobs: &Knobs,
    ctl: &ControlSpec,
    armed: bool,
) -> Result<CellRun, String> {
    validate_cell_ctl(sc, ctl)?;
    let wall0 = Instant::now();
    let mut events = 0u64;
    let mut recorder = None;
    let mut device_busy_s = Vec::new();
    let summary = match sc.kind {
        Kind::Analytic => {
            let link = derated_link(&Link::infiniband_cx6(), sc.oversub);
            CellSummary::Analytic(run_analytic(
                sc.topology, sc.fleet, sc.policy, sc.ranks, knobs, &link,
            ))
        }
        Kind::Event => {
            let (backends, tier) =
                build_fleet(sc.topology, sc.ranks, sc.fleet, &Link::infiniband_cx6());
            let sim_cfg = EventSimConfig {
                ranks: sc.ranks,
                materials: knobs.materials,
                samples_per_request: knobs.samples_per_request,
                requests_per_burst: knobs.requests_per_burst,
                mir_every: knobs.mir_every,
                mir_samples: knobs.mir_samples,
                arrival: sc.arrival,
                batching: if sc.window_us > 0.0 {
                    Batching::Window {
                        window_s: sc.window_us * 1e-6,
                        max_batch: knobs.max_batch,
                    }
                } else {
                    Batching::Off
                },
                horizon_s: knobs.horizon_s,
                seed: knobs.seed,
            };
            let mut sim = match build_fabric_spec(sc.topology, sc.ranks, sc.fleet, sc.oversub) {
                Some(spec) => {
                    EventSim::with_fabric(backends, sc.policy, sim_cfg, tier.hermit, tier.mir, spec)
                }
                None => EventSim::with_tiers(backends, sc.policy, sim_cfg, tier.hermit, tier.mir),
            };
            if armed {
                sim.arm_trace();
            }
            if !ctl.trace.is_empty() {
                sim.with_control(&ctl.trace);
            }
            sim.run_to_completion();
            events = sim.events_processed();
            device_busy_s = sim.device_busy_s().to_vec();
            recorder = sim.take_recorder();
            CellSummary::Event(sim.summary())
        }
        Kind::Cog => {
            let (backends, tier) =
                build_fleet(sc.topology, sc.ranks, sc.fleet, &Link::infiniband_cx6());
            let sim_cfg = CogSimConfig {
                ranks: sc.ranks,
                timesteps: knobs.timesteps,
                compute_s: knobs.compute_s,
                compute_jitter_s: 0.0,
                requests_per_step: knobs.requests_per_step,
                models: sc.models,
                samples_per_request: knobs.samples_per_request,
                mir_every: knobs.mir_every,
                mir_samples: knobs.mir_samples,
                overlap: sc.overlap,
                swap_s: sc.swap_s,
                residency_slots: knobs.residency_slots,
                batching: if sc.window_us > 0.0 {
                    Batching::Window {
                        window_s: sc.window_us * 1e-6,
                        max_batch: knobs.max_batch,
                    }
                } else {
                    Batching::Off
                },
                seed: knobs.seed,
            };
            let mut sim = match build_fabric_spec(sc.topology, sc.ranks, sc.fleet, sc.oversub) {
                Some(spec) => {
                    CogSim::with_fabric(backends, sc.policy, sim_cfg, tier.hermit, tier.mir, spec)
                }
                None => CogSim::with_tiers(backends, sc.policy, sim_cfg, tier.hermit, tier.mir),
            };
            if armed {
                sim.arm_trace();
            }
            if !ctl.is_static() {
                sim.with_control(&ctl.trace, ctl.autoscaler);
            }
            sim.run_to_completion();
            events = sim.events_processed();
            device_busy_s = sim.device_busy_s().to_vec();
            recorder = sim.take_recorder();
            CellSummary::Cog(sim.summary())
        }
        Kind::Fluid => CellSummary::Fluid(fluid::solve_cell(
            sc.topology,
            sc.fleet,
            sc.policy,
            sc.ranks,
            sc.models,
            sc.swap_s,
            sc.overlap,
            sc.oversub,
            sc.window_us,
            knobs,
        )),
    };
    let wall_s = wall0.elapsed().as_secs_f64();
    let timing = CellTiming {
        wall_ms: wall_s * 1e3,
        events,
        events_per_s: if wall_s > 0.0 { events as f64 / wall_s } else { 0.0 },
    };
    Ok(CellRun { result: CellResult { scenario: *sc, summary }, timing, recorder, device_busy_s })
}

/// Run every cell of a grid, in expansion order, on all cores.
pub fn run_grid(grid: &Grid) -> GridResult {
    run_grid_threads(grid, 0)
}

/// Run every cell of a grid on a work-stealing pool of `threads`
/// workers (`0` = all cores, `1` = the exact legacy sequential path).
/// Cells are independent and individually deterministic, and results
/// are collected keyed by cell index, so the output — and therefore
/// every JSON report derived from it — is byte-identical at any
/// thread count.
pub fn run_grid_threads(grid: &Grid, threads: usize) -> GridResult {
    run_grid_threads_full(grid, threads, false).split().0
}

/// An executed grid with the per-cell side-channels kept: timings
/// always, recorders when the run was armed.
#[derive(Debug)]
pub struct GridRun {
    pub grid: Grid,
    pub runs: Vec<CellRun>,
}

impl GridRun {
    /// Split into the classic [`GridResult`] (what every report layer
    /// consumes) plus the per-cell timings and recorders, all in
    /// expansion order.
    #[allow(clippy::type_complexity)]
    pub fn split(self) -> (GridResult, Vec<CellTiming>, Vec<Option<Box<Recorder>>>) {
        let mut cells = Vec::with_capacity(self.runs.len());
        let mut timings = Vec::with_capacity(self.runs.len());
        let mut recorders = Vec::with_capacity(self.runs.len());
        for run in self.runs {
            cells.push(run.result);
            timings.push(run.timing);
            recorders.push(run.recorder);
        }
        (GridResult { grid: self.grid, cells }, timings, recorders)
    }
}

/// As [`run_grid_threads`], keeping the per-cell side-channels.
/// Cells stay independent and individually deterministic, and the
/// pool's map preserves input order, so armed traces are
/// byte-identical at any thread count (`rust/tests/trace_props.rs`).
pub fn run_grid_threads_full(grid: &Grid, threads: usize, armed: bool) -> GridRun {
    let runs = workpool::Pool::new(threads).map(grid.cells(), |_, sc| {
        match try_run_cell_full(&sc, &grid.knobs, &grid.axes.control(sc.control), armed) {
            Ok(run) => run,
            Err(why) => panic!("{why}"),
        }
    });
    GridRun { grid: grid.clone(), runs }
}

// ------------------------------------------------ legacy: analytic

/// One (topology, policy, oversubscription) cell of the analytic
/// sweep.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub hydra: WorkloadSummary,
    pub mir: WorkloadSummary,
    pub makespan_s: f64,
    pub backends: Vec<BackendReport>,
}

/// The full analytic sweep.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config: CampaignConfig,
    pub scenarios: Vec<ScenarioResult>,
}

impl CampaignResult {
    /// Look up the baseline cell of a (topology, policy) pair: the
    /// non-blocking 1:1 cell when it was swept, otherwise the first
    /// swept oversubscription (so the classic lookup stays total
    /// over any `fabric_oversubs` configuration).
    pub fn scenario(&self, topology: Topology, policy: Policy) -> &ScenarioResult {
        self.scenario_at(topology, policy, 1.0)
            .or_else(|| {
                self.scenarios
                    .iter()
                    .find(|s| s.topology == topology && s.policy == policy)
            })
            .expect("campaign ran every (topology, policy) cell")
    }

    /// Look up one cell at an explicit oversubscription factor.
    pub fn scenario_at(
        &self,
        topology: Topology,
        policy: Policy,
        oversub: f64,
    ) -> Option<&ScenarioResult> {
        self.scenarios
            .iter()
            .find(|s| s.topology == topology && s.policy == policy && s.oversub == oversub)
    }
}

fn analytic_to_scenario_result(sc: &Scenario, summary: AnalyticSummary) -> ScenarioResult {
    ScenarioResult {
        topology: sc.topology,
        policy: sc.policy,
        oversub: sc.oversub,
        hydra: summary.hydra,
        mir: summary.mir,
        makespan_s: summary.makespan_s,
        backends: summary.backends,
    }
}

/// Run one (topology, policy) scenario at 1:1 oversubscription.
pub fn run_scenario(topology: Topology, policy: Policy, cfg: &CampaignConfig) -> ScenarioResult {
    run_scenario_with_link(topology, policy, cfg, &Link::infiniband_cx6())
}

/// Run one analytic cell at an explicit oversubscription factor.
pub fn run_scenario_at(
    topology: Topology,
    policy: Policy,
    oversub: f64,
    cfg: &CampaignConfig,
) -> ScenarioResult {
    let link = derated_link(&Link::infiniband_cx6(), oversub);
    let mut s = run_scenario_with_link(topology, policy, cfg, &link);
    s.oversub = oversub;
    s
}

/// As [`run_scenario`], with an explicit pool link — the link
/// ablation behind the Fig-15/16 anchor test (swap the Infiniband
/// model for [`Link::local`] to measure the pure remote overhead).
pub fn run_scenario_with_link(
    topology: Topology,
    policy: Policy,
    cfg: &CampaignConfig,
    pool_link: &Link,
) -> ScenarioResult {
    let knobs = cfg.grid().knobs;
    let summary =
        run_analytic(topology, Fleet::DefaultPool, policy, cfg.ranks, &knobs, pool_link);
    ScenarioResult {
        topology,
        policy,
        oversub: 1.0,
        hydra: summary.hydra,
        mir: summary.mir,
        makespan_s: summary.makespan_s,
        backends: summary.backends,
    }
}

/// Run the full analytic sweep: every topology under every routing
/// policy, across the fabric oversubscription axis (all-local
/// topologies run the single 1:1 cell — no fabric to derate).
pub fn run_campaign(cfg: &CampaignConfig) -> CampaignResult {
    let grid = cfg.grid();
    let scenarios = grid
        .cells()
        .iter()
        .map(|sc| match run_cell(sc, &grid.knobs).summary {
            CellSummary::Analytic(summary) => analytic_to_scenario_result(sc, summary),
            _ => unreachable!("analytic grid produced a non-analytic cell"),
        })
        .collect();
    CampaignResult { config: cfg.clone(), scenarios }
}

// --------------------------------------------------- legacy: event

/// One (topology, policy, arrival, ranks, window, oversub) cell.
#[derive(Debug, Clone)]
pub struct EventScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    pub arrival: ArrivalProcess,
    pub ranks: usize,
    pub window_us: f64,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub summary: EventSummary,
}

/// The full event-mode sweep.
#[derive(Debug, Clone)]
pub struct EventCampaignResult {
    pub config: EventCampaignConfig,
    pub scenarios: Vec<EventScenarioResult>,
}

impl EventCampaignResult {
    /// Look up one cell (`arrival_key` as in [`ArrivalProcess::key`]).
    pub fn scenario(
        &self,
        topology: Topology,
        policy: Policy,
        arrival_key: &str,
        ranks: usize,
        window_us: f64,
        oversub: f64,
    ) -> Option<&EventScenarioResult> {
        self.scenarios.iter().find(|s| {
            s.topology == topology
                && s.policy == policy
                && s.arrival.key() == arrival_key
                && s.ranks == ranks
                && s.window_us == window_us
                && s.oversub == oversub
        })
    }
}

fn event_cell_scenario(
    topology: Topology,
    policy: Policy,
    arrival: ArrivalProcess,
    ranks: usize,
    window_us: f64,
    oversub: f64,
    cfg: &EventCampaignConfig,
) -> Scenario {
    Scenario {
        kind: Kind::Event,
        topology,
        fleet: Fleet::DefaultPool,
        policy,
        ranks,
        arrival,
        window_us,
        models: cfg.materials,
        swap_s: 0.0,
        overlap: 0.0,
        oversub,
        control: 0,
    }
}

fn event_to_scenario_result(sc: &Scenario, summary: EventSummary) -> EventScenarioResult {
    EventScenarioResult {
        topology: sc.topology,
        policy: sc.policy,
        arrival: sc.arrival,
        ranks: sc.ranks,
        window_us: sc.window_us,
        oversub: sc.oversub,
        summary,
    }
}

/// Run one event-mode cell.  Pooled/hybrid topologies route remote
/// dispatches through the flow-level fabric at `oversub`; the
/// all-local topology has no shared links.
pub fn run_event_scenario(
    topology: Topology,
    policy: Policy,
    arrival: ArrivalProcess,
    ranks: usize,
    window_us: f64,
    oversub: f64,
    cfg: &EventCampaignConfig,
) -> EventScenarioResult {
    let sc = event_cell_scenario(topology, policy, arrival, ranks, window_us, oversub, cfg);
    match run_cell(&sc, &cfg.grid().knobs).summary {
        CellSummary::Event(summary) => event_to_scenario_result(&sc, summary),
        _ => unreachable!("event cell produced a non-event summary"),
    }
}

/// Run the full event-mode sweep.
pub fn run_event_campaign(cfg: &EventCampaignConfig) -> EventCampaignResult {
    let grid = cfg.grid();
    let scenarios = grid
        .cells()
        .iter()
        .map(|sc| match run_cell(sc, &grid.knobs).summary {
            CellSummary::Event(summary) => event_to_scenario_result(sc, summary),
            _ => unreachable!("event grid produced a non-event cell"),
        })
        .collect();
    EventCampaignResult { config: cfg.clone(), scenarios }
}

// ----------------------------------------------------- legacy: cog

/// One (topology, policy, ranks, models, swap, overlap, oversub) cell.
#[derive(Debug, Clone)]
pub struct CogScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    pub ranks: usize,
    pub models: usize,
    pub swap_s: f64,
    pub overlap: f64,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub summary: CogSummary,
}

/// The full coupled sweep.
#[derive(Debug, Clone)]
pub struct CogCampaignResult {
    pub config: CogCampaignConfig,
    pub scenarios: Vec<CogScenarioResult>,
}

impl CogCampaignResult {
    /// Look up one cell.
    #[allow(clippy::too_many_arguments)]
    pub fn scenario(
        &self,
        topology: Topology,
        policy: Policy,
        ranks: usize,
        models: usize,
        swap_s: f64,
        overlap: f64,
        oversub: f64,
    ) -> Option<&CogScenarioResult> {
        self.scenarios.iter().find(|s| {
            s.topology == topology
                && s.policy == policy
                && s.ranks == ranks
                && s.models == models
                && s.swap_s == swap_s
                && s.overlap == overlap
                && s.oversub == oversub
        })
    }
}

fn cog_to_scenario_result(sc: &Scenario, summary: CogSummary) -> CogScenarioResult {
    CogScenarioResult {
        topology: sc.topology,
        policy: sc.policy,
        ranks: sc.ranks,
        models: sc.models,
        swap_s: sc.swap_s,
        overlap: sc.overlap,
        oversub: sc.oversub,
        summary,
    }
}

/// Run one coupled cell.  Pooled/hybrid topologies route remote
/// dispatches and residency swaps through the flow-level fabric at
/// `oversub`; the all-local topology has no shared links.
#[allow(clippy::too_many_arguments)]
pub fn run_cog_scenario(
    topology: Topology,
    policy: Policy,
    ranks: usize,
    models: usize,
    swap_s: f64,
    overlap: f64,
    oversub: f64,
    cfg: &CogCampaignConfig,
) -> CogScenarioResult {
    let sc = Scenario {
        kind: Kind::Cog,
        topology,
        fleet: Fleet::DefaultPool,
        policy,
        ranks,
        arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        window_us: cfg.window_us,
        models,
        swap_s,
        overlap,
        oversub,
        control: 0,
    };
    match run_cell(&sc, &cfg.grid().knobs).summary {
        CellSummary::Cog(summary) => cog_to_scenario_result(&sc, summary),
        _ => unreachable!("cog cell produced a non-cog summary"),
    }
}

/// Run the full coupled sweep.
pub fn run_cog_campaign(cfg: &CogCampaignConfig) -> CogCampaignResult {
    let grid = cfg.grid();
    let scenarios = grid
        .cells()
        .iter()
        .map(|sc| match run_cell(sc, &grid.knobs).summary {
            CellSummary::Cog(summary) => cog_to_scenario_result(sc, summary),
            _ => unreachable!("cog grid produced a non-cog cell"),
        })
        .collect();
    CogCampaignResult { config: cfg.clone(), scenarios }
}

// ------------------------------------------------- control campaign

/// The control-plane study: a fixed list of coupled-engine cells that
/// pins the paper's resilience story — how each coupling topology
/// absorbs a mid-run backend loss, a fabric brown-out, a rank
/// checkpoint/restart, and whether a reactive autoscaler can track
/// the statically-provisioned optimum.
#[derive(Debug, Clone)]
pub struct ControlCampaignConfig {
    /// MPI ranks (local topology gets one GPU per rank; the pooled
    /// fleet gets the same accelerator count behind the fabric, so
    /// the one-backend loss removes the same fraction of capacity
    /// from both).
    pub ranks: usize,
    pub timesteps: usize,
    pub policy: Policy,
    /// Fabric oversubscription of the pooled cells.
    pub oversub: f64,
    pub seed: u64,
}

impl Default for ControlCampaignConfig {
    fn default() -> Self {
        ControlCampaignConfig {
            ranks: 4,
            timesteps: 8,
            policy: Policy::LeastOutstanding,
            oversub: 2.0,
            seed: 42,
        }
    }
}

impl ControlCampaignConfig {
    /// The fixed cell list: `(label, topology, control-spec key)`.
    /// Event times sit mid-run (steps are a few ms each); the pooled
    /// fleet is 4 remote A100s so local and pooled lose the same 1/4
    /// of their devices in the `leave` cells.
    pub fn cells(&self) -> Vec<(String, Topology, ControlSpec)> {
        [
            ("local/static", Topology::Local, "static"),
            ("local/leave", Topology::Local, "leave:0@10300"),
            ("pooled/static", Topology::Pooled, "static"),
            ("pooled/leave", Topology::Pooled, "leave:0@10300"),
            ("pooled/degrade", Topology::Pooled, "degrade:0.25@6000+restore@20000"),
            ("pooled/rankfail", Topology::Pooled, "rankfail:1@10000"),
            ("pooled/auto", Topology::Pooled, "auto:2:1-4:100:1000"),
        ]
        .into_iter()
        .map(|(label, topology, key)| {
            (label.to_string(), topology, ControlSpec::parse(key).expect("valid spec"))
        })
        .collect()
    }

    fn scenario(&self, topology: Topology) -> Scenario {
        Scenario {
            kind: Kind::Cog,
            topology,
            // same device count in and out of the pool: the loss cells
            // compare like against like
            fleet: Fleet::Mixed { gpus: self.ranks as u16, rdus: 0 },
            policy: self.policy,
            ranks: self.ranks,
            arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
            window_us: 0.0,
            models: 8,
            swap_s: 0.0,
            overlap: 0.0,
            oversub: self.oversub,
            control: 0,
        }
    }

    fn knobs(&self) -> Knobs {
        Knobs { timesteps: self.timesteps, seed: self.seed, ..Knobs::default() }
    }
}

/// One executed control-campaign cell.
#[derive(Debug, Clone)]
pub struct ControlCellResult {
    pub label: String,
    pub topology: Topology,
    pub control: ControlSpec,
    pub summary: CogSummary,
}

/// The executed control campaign.
#[derive(Debug, Clone)]
pub struct ControlCampaignResult {
    pub config: ControlCampaignConfig,
    pub cells: Vec<ControlCellResult>,
}

impl ControlCampaignResult {
    /// Look up one cell by label.
    pub fn cell(&self, label: &str) -> &ControlCellResult {
        self.cells
            .iter()
            .find(|c| c.label == label)
            .unwrap_or_else(|| panic!("control campaign has no cell {label:?}"))
    }

    /// TTS under one-backend loss over the static TTS of the same
    /// topology (1.0 = the loss was fully absorbed).
    pub fn loss_ratio(&self, topology_key: &str) -> f64 {
        let stat = self.cell(&format!("{topology_key}/static"));
        let loss = self.cell(&format!("{topology_key}/leave"));
        loss.summary.time_to_solution_s / stat.summary.time_to_solution_s
    }

    /// Autoscaled TTS over the statically-provisioned optimum (the
    /// all-backends-active static pooled cell).
    pub fn autoscaler_factor(&self) -> f64 {
        self.cell("pooled/auto").summary.time_to_solution_s
            / self.cell("pooled/static").summary.time_to_solution_s
    }
}

/// Run the control-plane study (sequential: seven cells, milliseconds
/// of wall time).
pub fn run_control_campaign(cfg: &ControlCampaignConfig) -> ControlCampaignResult {
    let knobs = cfg.knobs();
    let cells = cfg
        .cells()
        .into_iter()
        .map(|(label, topology, control)| {
            let sc = cfg.scenario(topology);
            match run_cell_ctl(&sc, &knobs, &control).summary {
                CellSummary::Cog(summary) => {
                    ControlCellResult { label, topology, control, summary }
                }
                _ => unreachable!("control campaign runs cog cells"),
            }
        })
        .collect();
    ControlCampaignResult { config: cfg.clone(), cells }
}

#[cfg(test)]
mod tests {
    use super::super::scenario::{oversubs_for, Axes};
    use super::*;

    fn quick_cfg() -> CampaignConfig {
        CampaignConfig { timesteps: 4, ..Default::default() }
    }

    #[test]
    fn campaign_covers_every_cell() {
        let result = run_campaign(&quick_cfg());
        assert_eq!(result.scenarios.len(), Topology::ALL.len() * Policy::ALL.len());
        for topo in Topology::ALL {
            for policy in Policy::ALL {
                let s = result.scenario(topo, policy);
                assert!(s.hydra.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.mir.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.makespan_s > 0.0);
            }
        }
    }

    #[test]
    fn scenarios_conserve_samples() {
        // every scenario of a sweep sees the same workload; each must
        // route exactly the submitted sample volume
        let result = run_campaign(&quick_cfg());
        let expect_hydra = result.scenarios[0].hydra.samples;
        let expect_mir = result.scenarios[0].mir.samples;
        assert!(expect_hydra > 0 && expect_mir > 0);
        for s in &result.scenarios {
            assert_eq!(s.hydra.samples, expect_hydra, "{:?}/{:?}", s.topology, s.policy);
            assert_eq!(s.mir.samples, expect_mir);
            let routed: u64 = s.backends.iter().map(|b| b.samples).sum();
            assert_eq!(routed, expect_hydra + expect_mir);
        }
    }

    #[test]
    fn local_topology_has_zero_link_overhead() {
        let s = run_scenario(Topology::Local, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.hydra.mean_link_overhead_s, 0.0);
        assert_eq!(s.mir.mean_link_overhead_s, 0.0);
    }

    #[test]
    fn pooled_topology_pays_the_link() {
        let s = run_scenario(Topology::Pooled, Policy::LatencyAware, &quick_cfg());
        assert!(s.hydra.mean_link_overhead_s > 0.0);
        // MIR payloads (2×2304 els/sample) dwarf Hermit's 42+30
        assert!(s.mir.mean_link_overhead_s > s.hydra.mean_link_overhead_s);
    }

    #[test]
    fn hybrid_keeps_mir_local() {
        let s = run_scenario(Topology::Hybrid, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.mir.mean_link_overhead_s, 0.0, "hot model must stay local");
        assert!(s.hydra.mean_link_overhead_s > 0.0, "long tail rides the link");
        // GPU backends saw only MIR traffic, the pool only Hermit
        let gpu_requests: u64 = s
            .backends
            .iter()
            .filter(|b| b.name.starts_with("gpu/"))
            .map(|b| b.requests)
            .sum();
        assert_eq!(gpu_requests, s.mir.requests);
    }

    #[test]
    fn json_is_deterministic() {
        let cfg = quick_cfg();
        let a = crate::util::json::write(&run_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_campaign(&cfg).to_json());
        assert_eq!(a, b);
        // and parses back
        assert!(crate::util::json::parse(&a).is_ok());
        assert!(a.contains("\"topology\":\"hybrid\""), "{}", &a[..200.min(a.len())]);
    }

    // ------------------------------------------------- event mode

    fn quick_event_cfg() -> EventCampaignConfig {
        EventCampaignConfig {
            rank_counts: vec![4],
            horizon_s: 0.05,
            ..Default::default()
        }
    }

    #[test]
    fn event_campaign_covers_every_cell() {
        let cfg = quick_event_cfg();
        let result = run_event_campaign(&cfg);
        let cells: usize = cfg
            .topologies
            .iter()
            .map(|&t| {
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.arrivals.len()
                    * cfg.windows_us.len()
                    * oversubs_for(t, &cfg.fabric_oversubs).len()
            })
            .sum();
        assert_eq!(result.scenarios.len(), cells);
        for s in &result.scenarios {
            assert!(s.summary.requests > 0, "{:?}/{:?}", s.topology, s.policy);
            assert!(s.summary.latency.p50_s > 0.0);
            assert!(s.summary.latency.p999_s >= s.summary.latency.p99_s);
        }
        // lookup works for an arbitrary cell; the local topology
        // collapses the oversubscription axis to the single 1:1 cell
        assert!(result
            .scenario(Topology::Pooled, Policy::LatencyAware, "poisson", 4, 200.0, 4.0)
            .is_some());
        assert!(result
            .scenario(Topology::Local, Policy::LatencyAware, "poisson", 4, 200.0, 4.0)
            .is_none());
        assert!(result
            .scenario(Topology::Local, Policy::LatencyAware, "poisson", 4, 200.0, 1.0)
            .is_some());
        assert!(result
            .scenario(Topology::Hybrid, Policy::LatencyAware, "poisson", 4, 200.0, 1.0)
            .is_none());
    }

    #[test]
    fn event_workload_identical_across_cells_of_one_arrival() {
        // Open-loop arrivals do not depend on service times, so every
        // (topology, policy, window) cell of a given arrival process
        // and rank count must see the same submitted request volume.
        let result = run_event_campaign(&quick_event_cfg());
        for key in ["synchronized", "poisson"] {
            let volumes: Vec<u64> = result
                .scenarios
                .iter()
                .filter(|s| s.arrival.key() == key && s.ranks == 4)
                .map(|s| s.summary.requests)
                .collect();
            assert!(!volumes.is_empty());
            assert!(
                volumes.iter().all(|&v| v == volumes[0]),
                "{key}: {volumes:?}"
            );
        }
    }

    #[test]
    fn event_json_is_deterministic_and_parses() {
        let cfg = quick_event_cfg();
        let a = crate::util::json::write(&run_event_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_event_campaign(&cfg).to_json());
        assert_eq!(a, b);
        let doc = crate::util::json::parse(&a).unwrap();
        let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
        for s in scenarios {
            for field in ["topology", "policy", "arrival", "ranks", "window_us", "summary"] {
                assert!(s.get(field).is_some(), "missing {field}");
            }
            let sum = s.get("summary").unwrap();
            for field in ["p50_us", "p99_us", "p999_us", "histogram", "slowdown_max"] {
                assert!(sum.get(field).is_some(), "missing summary.{field}");
            }
        }
    }

    #[test]
    fn event_tables_cover_the_sweep() {
        let cfg = quick_event_cfg();
        let result = run_event_campaign(&cfg);
        let tables = result.tables();
        assert_eq!(tables.len(), cfg.topologies.len());
        for (table, &topo) in tables.iter().zip(&cfg.topologies) {
            assert_eq!(
                table.x.len(),
                cfg.policies.len()
                    * cfg.arrivals.len()
                    * cfg.windows_us.len()
                    * oversubs_for(topo, &cfg.fabric_oversubs).len()
            );
            assert!(table.series("p999_us").is_some());
            assert!(table.series("contention_us").is_some());
        }
    }

    // ------------------------------------------------ cogsim mode

    fn quick_cog_cfg() -> CogCampaignConfig {
        CogCampaignConfig {
            policies: vec![Policy::RoundRobin, Policy::ModelAffinity],
            rank_counts: vec![4],
            fabric_oversubs: vec![1.0, 4.0],
            timesteps: 4,
            ..Default::default()
        }
    }

    #[test]
    fn cog_campaign_covers_every_cell() {
        let cfg = quick_cog_cfg();
        let result = run_cog_campaign(&cfg);
        let cells: usize = cfg
            .topologies
            .iter()
            .map(|&t| {
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.models_per_rank.len()
                    * cfg.swap_costs_s.len()
                    * cfg.overlaps.len()
                    * oversubs_for(t, &cfg.fabric_oversubs).len()
            })
            .sum();
        assert_eq!(result.scenarios.len(), cells);
        for s in &result.scenarios {
            assert!(s.summary.time_to_solution_s > 0.0, "{:?}/{:?}", s.topology, s.policy);
            assert_eq!(s.summary.timesteps as usize, cfg.timesteps);
            assert_eq!(
                s.summary.requests,
                (s.ranks * cfg.timesteps * cfg.requests_per_step) as u64
            );
            assert_eq!(s.summary.steps.len(), cfg.timesteps);
        }
        assert!(result
            .scenario(Topology::Pooled, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 4.0)
            .is_some());
        assert!(result
            .scenario(Topology::Local, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 4.0)
            .is_none());
        assert!(result
            .scenario(Topology::Hybrid, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 1.0)
            .is_none());
    }

    #[test]
    fn cog_json_is_deterministic_and_parses() {
        let cfg = quick_cog_cfg();
        let a = crate::util::json::write(&run_cog_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_cog_campaign(&cfg).to_json());
        assert_eq!(a, b);
        let doc = crate::util::json::parse(&a).unwrap();
        let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
        for s in scenarios {
            for field in ["topology", "policy", "ranks", "models", "swap_us", "overlap"] {
                assert!(s.get(field).is_some(), "missing {field}");
            }
            let sum = s.get("summary").unwrap();
            for field in [
                "time_to_solution_us",
                "total_compute_us",
                "total_queue_us",
                "total_swap_us",
                "total_network_us",
                "total_service_us",
                "straggler_counts",
                "steps",
            ] {
                assert!(sum.get(field).is_some(), "missing summary.{field}");
            }
            let steps = sum.get("steps").unwrap().as_array().unwrap();
            assert_eq!(steps.len(), cfg.timesteps);
        }
    }

    #[test]
    fn cog_tables_cover_the_sweep() {
        let cfg = quick_cog_cfg();
        let result = run_cog_campaign(&cfg);
        let tables = result.tables();
        assert_eq!(tables.len(), cfg.topologies.len());
        for (table, &topo) in tables.iter().zip(&cfg.topologies) {
            assert_eq!(
                table.x.len(),
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.models_per_rank.len()
                    * cfg.swap_costs_s.len()
                    * cfg.overlaps.len()
                    * oversubs_for(topo, &cfg.fabric_oversubs).len()
            );
            assert!(table.series("tts_ms").is_some());
            assert!(table.series("swap_ms").is_some());
            assert!(table.series("contention_ms").is_some());
        }
    }

    #[test]
    fn cog_local_topology_pays_no_network_on_the_critical_path() {
        let cfg = quick_cog_cfg();
        let s =
            run_cog_scenario(Topology::Local, Policy::LatencyAware, 4, 8, 0.0, 0.0, 1.0, &cfg);
        assert_eq!(s.summary.total_network_s, 0.0);
        assert_eq!(s.summary.total_contention_s, 0.0);
        let p =
            run_cog_scenario(Topology::Pooled, Policy::LatencyAware, 4, 8, 0.0, 0.0, 1.0, &cfg);
        assert!(p.summary.total_network_s > 0.0, "pool rides the link");
    }

    #[test]
    fn cog_fabric_oversubscription_never_speeds_the_pool_up() {
        // The knob's contract at the campaign level: pooled TTS is
        // monotone non-decreasing in oversubscription, and the
        // all-local topology is untouched by it.
        let cfg = quick_cog_cfg();
        let tts = |oversub: f64| {
            run_cog_scenario(Topology::Pooled, Policy::RoundRobin, 4, 8, 0.0, 0.0, oversub, &cfg)
                .summary
                .time_to_solution_s
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let t = tts(oversub);
            assert!(t >= last - 1e-12, "oversub {oversub}: {t} < {last}");
            last = t;
        }
    }

    // ------------------------------------------------ unified grid

    #[test]
    fn one_grid_runs_every_kind() {
        // One declarative config, three engines: the mixed fleet
        // rides all of them without per-mode wiring.
        let grid = Grid {
            axes: Axes {
                kinds: Kind::ALL.to_vec(),
                topologies: vec![Topology::Pooled],
                fleets: vec![Fleet::Mixed { gpus: 2, rdus: 1 }],
                policies: vec![Policy::LatencyAware],
                rank_counts: vec![4],
                fabric_oversubs: vec![1.0],
                ..Axes::default()
            },
            knobs: Knobs { timesteps: 3, horizon_s: 0.05, ..Knobs::default() },
        };
        let result = run_grid(&grid);
        assert_eq!(result.cells.len(), 4);
        let analytic = result.cells[0].analytic().expect("kind order");
        assert!(analytic.hydra.requests > 0);
        assert_eq!(analytic.backends.len(), 3, "2 GPUs + 1 RDU in the pool");
        let event = result.cells[1].event().expect("kind order");
        assert!(event.requests > 0 && event.mean_link_overhead_s > 0.0);
        let cog = result.cells[2].cog().expect("kind order");
        assert!(cog.time_to_solution_s > 0.0);
        assert!(cog.total_network_s > 0.0, "mixed pool is remote");
        let fluid = result.cells[3].fluid().expect("kind order");
        assert!(fluid.time_to_solution_s > 0.0);
        assert!(fluid.total_network_s > 0.0, "mixed pool is remote");
        assert!(fluid.converged);
    }

    #[test]
    fn try_run_cell_ctl_rejects_oversized_autoscaler() {
        // auto:4:1-8:... on a 2-member pool: parses fine, but the
        // cell's hermit tier can't satisfy max_active = 8
        let ctl = ControlSpec::parse("auto:4:1-8:100:1000").expect("parses in isolation");
        let sc = Scenario {
            kind: Kind::Cog,
            topology: Topology::Pooled,
            fleet: Fleet::DefaultPool,
            policy: Policy::RoundRobin,
            ranks: 4,
            arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
            window_us: 0.0,
            models: 8,
            swap_s: 0.0,
            overlap: 0.0,
            oversub: 1.0,
            control: 0,
        };
        let err = try_run_cell_ctl(&sc, &Knobs::default(), &ctl).expect_err("tier is 2");
        assert!(err.contains("auto:4:1-8"), "names the spec: {err}");
        assert!(err.contains("tier size"), "names the constraint: {err}");
    }

    // ------------------------------------------- control campaign

    #[test]
    fn control_campaign_cell_list_is_fixed() {
        let cfg = ControlCampaignConfig::default();
        let cells = cfg.cells();
        let labels: Vec<&str> = cells.iter().map(|(l, _, _)| l.as_str()).collect();
        assert_eq!(
            labels,
            [
                "local/static",
                "local/leave",
                "pooled/static",
                "pooled/leave",
                "pooled/degrade",
                "pooled/rankfail",
                "pooled/auto",
            ]
        );
        // topology is encoded in the label prefix
        for (label, topology, _) in &cells {
            let prefix = if *topology == Topology::Local { "local/" } else { "pooled/" };
            assert!(label.starts_with(prefix), "{label}");
        }
        // the static cells carry an empty trace; every dynamic cell a
        // non-static spec
        for (label, _, control) in &cells {
            assert_eq!(label.ends_with("/static"), control.is_static(), "{label}");
        }
    }

    #[test]
    fn control_campaign_lookups_cover_every_cell() {
        let cfg = ControlCampaignConfig { timesteps: 2, ..Default::default() };
        let result = run_control_campaign(&cfg);
        assert_eq!(result.cells.len(), cfg.cells().len());
        for (label, topology, _) in cfg.cells() {
            let cell = result.cell(&label);
            assert_eq!(cell.topology, topology, "{label}");
            assert!(cell.summary.time_to_solution_s.is_finite(), "{label}");
            assert!(cell.summary.submitted > 0, "{label}");
        }
        for key in ["local", "pooled"] {
            let r = result.loss_ratio(key);
            assert!(r.is_finite() && r > 0.0, "{key}: {r}");
        }
        let f = result.autoscaler_factor();
        assert!(f.is_finite() && f > 0.0, "{f}");
    }
}
