//! One regenerator per evaluation figure (Figs. 4–20).
//!
//! Each function rebuilds the figure's series from the calibrated
//! device models ([`crate::devices`], [`crate::rdu`],
//! [`crate::netsim`]) over the paper's mini-batch ladder and returns
//! them as [`Table`]s.  Shape invariants for every figure are pinned
//! in `rust/tests/paper_shapes.rs`; EXPERIMENTS.md records
//! paper-vs-reproduced numbers.

use anyhow::{bail, Result};

use crate::devices::{profiles, Api, Gpu, GpuModel, PAPER_BATCHES};
use crate::netsim::{payload_bytes, Link};
use crate::rdu::{RduApi, RduModel};

use super::table::Table;

/// All regenerable figure ids.
pub const FIGURES: [&str; 17] = [
    "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    "fig13", "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20",
];

/// A regenerated figure: one or more tables.
#[derive(Debug)]
pub struct FigureResult {
    pub id: &'static str,
    pub caption: &'static str,
    pub tables: Vec<Table>,
}

/// Regenerate one figure by id.
pub fn run_figure(id: &str) -> Result<FigureResult> {
    match id {
        "fig4" => Ok(fig4()),
        "fig5" => Ok(fig5()),
        "fig6" => Ok(fig6()),
        "fig7" => Ok(fig7()),
        "fig8" => Ok(fig8()),
        "fig9" => Ok(fig9()),
        "fig10" => Ok(fig10()),
        "fig11" => Ok(fig11()),
        "fig12" => Ok(fig12()),
        "fig13" => Ok(fig13()),
        "fig14" => Ok(fig14()),
        "fig15" => Ok(fig15()),
        "fig16" => Ok(fig16()),
        "fig17" => Ok(fig17()),
        "fig18" => Ok(fig18()),
        "fig19" => Ok(fig19()),
        "fig20" => Ok(fig20()),
        other => bail!("unknown figure {other:?}; have {FIGURES:?}"),
    }
}

fn batches() -> Vec<usize> {
    PAPER_BATCHES.to_vec()
}

fn gpu_model(gpu: Gpu, api: Api) -> GpuModel {
    GpuModel::new(gpu, api, profiles::hermit())
}

fn latency_ms_series(m: &GpuModel) -> Vec<f64> {
    batches().iter().map(|&b| m.latency_s(b) * 1e3).collect()
}

fn throughput_series(m: &GpuModel) -> Vec<f64> {
    batches().iter().map(|&b| m.throughput(b)).collect()
}

// --------------------------------------------------------- Figs 4-7

fn fig4() -> FigureResult {
    let mut t = Table::new(
        "Fig 4: Hermit inference latency (ms), Nvidia GPUs, naive PyTorch",
        "mini_batch",
    );
    t.set_x(batches());
    for name in Gpu::ALL_NVIDIA {
        let m = gpu_model(Gpu::by_name(name).unwrap(), Api::NaivePyTorch);
        t.add_series(name, latency_ms_series(&m));
    }
    FigureResult {
        id: "fig4",
        caption: "Hermit latency on P100/V100/A100 (PyTorch Python API)",
        tables: vec![t],
    }
}

fn fig5() -> FigureResult {
    let mut t = Table::new(
        "Fig 5: Hermit inference throughput (samples/s), Nvidia GPUs, naive PyTorch",
        "mini_batch",
    );
    t.set_x(batches());
    for name in Gpu::ALL_NVIDIA {
        let m = gpu_model(Gpu::by_name(name).unwrap(), Api::NaivePyTorch);
        t.add_series(name, throughput_series(&m));
    }
    FigureResult {
        id: "fig5",
        caption: "Hermit throughput on P100/V100/A100 (PyTorch Python API)",
        tables: vec![t],
    }
}

fn fig6() -> FigureResult {
    let mut t = Table::new(
        "Fig 6: Hermit inference latency (ms), AMD GPUs, naive PyTorch",
        "mini_batch",
    );
    t.set_x(batches());
    for name in Gpu::ALL_AMD {
        let m = gpu_model(Gpu::by_name(name).unwrap(), Api::NaivePyTorch);
        t.add_series(name, latency_ms_series(&m));
    }
    FigureResult {
        id: "fig6",
        caption: "Hermit latency on MI50/MI100 (PyTorch/ROCm)",
        tables: vec![t],
    }
}

fn fig7() -> FigureResult {
    let a100 = gpu_model(Gpu::a100(), Api::NaivePyTorch);
    let mi100 = gpu_model(Gpu::mi100(), Api::NaivePyTorch);

    let mut lat = Table::new("Fig 7a: Hermit latency (ms), A100 vs MI100", "mini_batch");
    lat.set_x(batches());
    lat.add_series("A100", latency_ms_series(&a100));
    lat.add_series("MI100", latency_ms_series(&mi100));

    let mut thr = Table::new(
        "Fig 7b: Hermit throughput (samples/s), A100 vs MI100 (+TDP-normalised)",
        "mini_batch",
    );
    thr.set_x(batches());
    thr.add_series("A100", throughput_series(&a100));
    thr.add_series("MI100", throughput_series(&mi100));
    thr.add_series(
        "MI100_tdp_norm",
        batches()
            .iter()
            .map(|&b| mi100.throughput_tdp_normalised(b, a100.gpu.tdp_w))
            .collect(),
    );
    FigureResult {
        id: "fig7",
        caption: "A100 vs MI100 latency and TDP-normalised throughput",
        tables: vec![lat, thr],
    }
}

// -------------------------------------------------------- Figs 8-10

fn fig8() -> FigureResult {
    let mut t = Table::new(
        "Fig 8: Hermit latency (ms) on A100 across API configurations",
        "mini_batch",
    );
    t.set_x(batches());
    for api in Api::ALL {
        t.add_series(api.label(), latency_ms_series(&gpu_model(Gpu::a100(), api)));
    }
    FigureResult {
        id: "fig8",
        caption: "A100 Hermit latency: PyTorch / TensorRT / CUDA Graphs combos",
        tables: vec![t],
    }
}

fn fig9() -> FigureResult {
    let mut t = Table::new(
        "Fig 9: Hermit throughput (samples/s) on A100 across API configurations",
        "mini_batch",
    );
    t.set_x(batches());
    for api in Api::ALL {
        t.add_series(api.label(), throughput_series(&gpu_model(Gpu::a100(), api)));
    }
    FigureResult {
        id: "fig9",
        caption: "A100 Hermit throughput: PyTorch / TensorRT / CUDA Graphs combos",
        tables: vec![t],
    }
}

fn fig10() -> FigureResult {
    // The paper shows 4 configurations for MIR (no C++ TensorRT).
    let mut t = Table::new(
        "Fig 10: MIR throughput (samples/s) on A100 across API configurations",
        "mini_batch",
    );
    t.set_x(batches());
    for api in [Api::NaivePyTorch, Api::TensorRt, Api::CudaGraphs, Api::TrtCudaGraphs] {
        let m = GpuModel::new(Gpu::a100(), api, profiles::mir());
        t.add_series(api.label(), throughput_series(&m));
    }
    FigureResult {
        id: "fig10",
        caption: "MIR throughput on A100 (torch2trt layernorm penalty visible on TRT paths)",
        tables: vec![t],
    }
}

// ------------------------------------------------------- Figs 11-14

fn heatmap(tiles: usize, id: &'static str, caption: &'static str) -> FigureResult {
    // Rows: micro-batch; columns: mini-batch.  Invalid cells
    // (micro > mini) are NaN, rendered blank in CSV consumers —
    // mirroring the paper's white squares.
    let m = RduModel::new(profiles::hermit(), tiles, RduApi::Python);
    let minis = batches();
    let micros = batches();
    let mut t = Table::new(
        format!("{caption} — latency (ms), rows = micro-batch"),
        "micro\\mini",
    );
    t.set_x(micros.clone());
    for &mini in &minis {
        let col: Vec<f64> = micros
            .iter()
            .map(|&micro| {
                if m.config_valid(mini, micro) {
                    m.latency_s(mini, micro) * 1e3
                } else {
                    f64::NAN
                }
            })
            .collect();
        t.add_series(format!("mini_{mini}"), col);
    }
    FigureResult { id, caption, tables: vec![t] }
}

fn fig11() -> FigureResult {
    heatmap(1, "fig11", "Fig 11: Hermit latency on 1/4 RDU (1 tile), mini x micro sweep")
}

fn fig12() -> FigureResult {
    heatmap(4, "fig12", "Fig 12: Hermit latency on 1 RDU (4 tiles), mini x micro sweep")
}

/// The four Fig-13/14 configurations.
fn rdu_configs() -> Vec<(&'static str, RduModel)> {
    vec![
        ("Python (naive)", RduModel::new(profiles::hermit(), 4, RduApi::Python)),
        (
            "Python (optimized)",
            RduModel::new(profiles::hermit(), 4, RduApi::PythonOptimized),
        ),
        (
            "C++ (optimized)",
            RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized),
        ),
        (
            "C++ (optimized, preferred MB)",
            RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized).with_preferred_mb(),
        ),
    ]
}

/// "Preferred MB": the paper makes *small adjustments* to the
/// mini-batch so it becomes a multiple of 6 (§V-C) — a power-of-2
/// mini-batch is never divisible by 6, so the hardware bonus needs
/// the adjusted size (e.g. 64 -> 66, 256 -> 258).
fn preferred_mini(b: usize) -> usize {
    (b.div_ceil(6)).max(1) * 6
}

fn fig13() -> FigureResult {
    let mut t = Table::new(
        "Fig 13: Hermit latency (ms) on 1 RDU, optimisation methods",
        "mini_batch",
    );
    t.set_x(batches());
    for (label, m) in rdu_configs() {
        if m.preferred_mb {
            t.add_series(
                label,
                batches()
                    .iter()
                    .map(|&b| m.latency_best_s(preferred_mini(b)) * 1e3)
                    .collect(),
            );
        } else {
            t.add_series(
                label,
                batches().iter().map(|&b| m.latency_best_s(b) * 1e3).collect(),
            );
        }
    }
    FigureResult {
        id: "fig13",
        caption: "RDU Hermit latency: Python naive / optimized placement / C++ / preferred-MB",
        tables: vec![t],
    }
}

fn fig14() -> FigureResult {
    let mut t = Table::new(
        "Fig 14: Hermit throughput (samples/s) on 1 RDU, optimisation methods",
        "mini_batch",
    );
    t.set_x(batches());
    for (label, m) in rdu_configs() {
        if m.preferred_mb {
            t.add_series(
                label,
                batches()
                    .iter()
                    .map(|&b| m.throughput_best(preferred_mini(b)))
                    .collect(),
            );
        } else {
            t.add_series(
                label,
                batches().iter().map(|&b| m.throughput_best(b)).collect(),
            );
        }
    }
    FigureResult {
        id: "fig14",
        caption: "RDU Hermit throughput under the Fig-13 configurations",
        tables: vec![t],
    }
}

// ------------------------------------------------------- Figs 15-16

fn remote_latency_s(m: &RduModel, link: &Link, b: usize) -> f64 {
    let p = &m.profile;
    link.remote_latency_s(m.latency_best_s(b), payload_bytes(p.input_elems, p.output_elems, b))
}

fn remote_throughput(m: &RduModel, link: &Link, b: usize) -> f64 {
    let p = &m.profile;
    link.remote_throughput(
        m.latency_best_s(b),
        payload_bytes(p.input_elems, p.output_elems, b),
        b,
    )
}

fn fig15() -> FigureResult {
    let py = RduModel::new(profiles::hermit(), 4, RduApi::PythonOptimized);
    let cpp = RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized);
    let link = Link::infiniband_cx6();

    let mut t = Table::new(
        "Fig 15: Hermit latency (ms) on 1 RDU — local vs remote",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series(
        "local Python",
        batches().iter().map(|&b| py.latency_best_s(b) * 1e3).collect(),
    );
    t.add_series(
        "local C++",
        batches().iter().map(|&b| cpp.latency_best_s(b) * 1e3).collect(),
    );
    t.add_series(
        "remote C++",
        batches().iter().map(|&b| remote_latency_s(&cpp, &link, b) * 1e3).collect(),
    );
    FigureResult {
        id: "fig15",
        caption: "RDU local vs remote latency (hand-optimised placement)",
        tables: vec![t],
    }
}

fn fig16() -> FigureResult {
    let py = RduModel::new(profiles::hermit(), 4, RduApi::PythonOptimized);
    let cpp = RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized);
    let link = Link::infiniband_cx6();

    let mut t = Table::new(
        "Fig 16: Hermit throughput (samples/s) on 1 RDU — local vs remote",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series(
        "local Python",
        batches().iter().map(|&b| py.throughput_best(b)).collect(),
    );
    t.add_series(
        "local C++",
        batches().iter().map(|&b| cpp.throughput_best(b)).collect(),
    );
    t.add_series(
        "remote C++",
        batches().iter().map(|&b| remote_throughput(&cpp, &link, b)).collect(),
    );
    FigureResult {
        id: "fig16",
        caption: "RDU local vs remote throughput (async double-buffered client)",
        tables: vec![t],
    }
}

// ------------------------------------------------------- Figs 17-19

/// The Fig-17/18 configuration set.
struct Comparison {
    a100_naive: GpuModel,
    a100_best: GpuModel,
    rdu_naive: RduModel,
    rdu_best: RduModel,
    link: Link,
}

impl Comparison {
    fn new() -> Comparison {
        Comparison {
            a100_naive: gpu_model(Gpu::a100(), Api::NaivePyTorch),
            a100_best: gpu_model(Gpu::a100(), Api::TrtCudaGraphs),
            rdu_naive: RduModel::new(profiles::hermit(), 4, RduApi::Python),
            rdu_best: RduModel::new(profiles::hermit(), 4, RduApi::CppOptimized),
            link: Link::infiniband_cx6(),
        }
    }
}

fn fig17() -> FigureResult {
    let c = Comparison::new();
    let mut t = Table::new(
        "Fig 17: Hermit latency (ms) — A100 vs 1 RDU configurations",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series("A100 naive", latency_ms_series(&c.a100_naive));
    t.add_series("A100 TRT+Graphs", latency_ms_series(&c.a100_best));
    t.add_series(
        "RDU local C++",
        batches().iter().map(|&b| c.rdu_best.latency_best_s(b) * 1e3).collect(),
    );
    t.add_series(
        "RDU remote C++",
        batches()
            .iter()
            .map(|&b| remote_latency_s(&c.rdu_best, &c.link, b) * 1e3)
            .collect(),
    );
    FigureResult {
        id: "fig17",
        caption: "Latency comparison: node-local A100 vs local/remote RDU",
        tables: vec![t],
    }
}

fn fig18() -> FigureResult {
    let c = Comparison::new();
    let mut t = Table::new(
        "Fig 18: Hermit throughput (samples/s) — A100 vs 1 RDU configurations",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series("A100 naive", throughput_series(&c.a100_naive));
    t.add_series("A100 TRT+Graphs", throughput_series(&c.a100_best));
    t.add_series(
        "RDU local C++",
        batches().iter().map(|&b| c.rdu_best.throughput_best(b)).collect(),
    );
    t.add_series(
        "RDU remote C++",
        batches()
            .iter()
            .map(|&b| remote_throughput(&c.rdu_best, &c.link, b))
            .collect(),
    );
    FigureResult {
        id: "fig18",
        caption: "Throughput comparison: node-local A100 vs local/remote RDU",
        tables: vec![t],
    }
}

fn fig19() -> FigureResult {
    let c = Comparison::new();
    let mut t = Table::new(
        "Fig 19: RDU-over-A100 throughput speedup (>1 favours the DataScale)",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series(
        "naive vs naive",
        batches()
            .iter()
            .map(|&b| c.rdu_naive.throughput_best(b) / c.a100_naive.throughput(b))
            .collect(),
    );
    t.add_series(
        "optimized local vs optimized local",
        batches()
            .iter()
            .map(|&b| c.rdu_best.throughput_best(b) / c.a100_best.throughput(b))
            .collect(),
    );
    t.add_series(
        "remote RDU vs optimized A100 (CogSim)",
        batches()
            .iter()
            .map(|&b| remote_throughput(&c.rdu_best, &c.link, b) / c.a100_best.throughput(b))
            .collect(),
    );
    // "we normalize the DataScale throughput by transistor count.
    // The A100 has 1.3x the transistor count of the DataScale RDU."
    let norm = c.a100_best.gpu.transistors_b / RduModel::TRANSISTORS_B;
    t.add_series(
        "remote RDU vs optimized A100, transistor-normalised",
        batches()
            .iter()
            .map(|&b| {
                norm * remote_throughput(&c.rdu_best, &c.link, b) / c.a100_best.throughput(b)
            })
            .collect(),
    );
    FigureResult {
        id: "fig19",
        caption: "Speedup factors for the three configuration pairs + transistor normalisation",
        tables: vec![t],
    }
}

// ------------------------------------------------------------ Fig 20

fn fig20() -> FigureResult {
    // "This comparison is done on a version of the MIR model without
    // layernorm to ensure the model would compile optimally on both
    // architectures."
    let profile = profiles::mir_noln();
    let a100_naive = GpuModel::new(Gpu::a100(), Api::NaivePyTorch, profile.clone());
    let a100_graphs = GpuModel::new(Gpu::a100(), Api::CudaGraphs, profile.clone());
    let rdu = RduModel::new(profile, 4, RduApi::CppOptimized);

    let mut t = Table::new(
        "Fig 20: MIR (no layernorm) throughput (samples/s) — A100 vs 1 RDU",
        "mini_batch",
    );
    t.set_x(batches());
    t.add_series("A100 naive", throughput_series(&a100_naive));
    t.add_series("A100 CUDA Graphs", throughput_series(&a100_graphs));
    t.add_series(
        "RDU local C++",
        batches().iter().map(|&b| rdu.throughput_best(b)).collect(),
    );
    t.add_series(
        "target (100K/s per rank)",
        vec![crate::workload::MirWorkload::TARGET_SAMPLES_PER_SEC_PER_RANK; batches().len()],
    );
    FigureResult {
        id: "fig20",
        caption: "MIR throughput vs the 100K samples/s/rank target",
        tables: vec![t],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_runs() {
        for id in FIGURES {
            let fig = run_figure(id).unwrap();
            assert_eq!(fig.id, id);
            assert!(!fig.tables.is_empty(), "{id}");
            for t in &fig.tables {
                assert!(!t.x.is_empty(), "{id}");
                assert!(!t.series.is_empty(), "{id}");
            }
        }
        assert!(run_figure("fig99").is_err());
    }

    #[test]
    fn heatmaps_mask_invalid_cells() {
        let fig = run_figure("fig11").unwrap();
        let t = &fig.tables[0];
        // micro=4 (row index 1), mini=1 (column "mini_1") is invalid.
        let col = t.series("mini_1").unwrap();
        assert!(col[1].is_nan()); // micro 4 > mini 1
        assert!(!col[0].is_nan()); // micro 1 <= mini 1
    }

    #[test]
    fn fig19_has_four_ratio_series() {
        let fig = run_figure("fig19").unwrap();
        assert_eq!(fig.tables[0].series.len(), 4);
    }

    #[test]
    fn fig20_includes_target_line() {
        let fig = run_figure("fig20").unwrap();
        let target = fig.tables[0].series("target (100K/s per rank)").unwrap();
        assert!(target.iter().all(|&v| v == 100_000.0));
    }
}
