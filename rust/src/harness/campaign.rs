//! Scenario campaigns: sweep Hydra/MIR request streams across
//! cluster **topologies** × routing **policies** and emit a
//! deterministic JSON summary (p50/p95/p99 latency, samples/s,
//! backend utilisation) — the multi-accelerator extension of the
//! paper's single-device evaluation.
//!
//! Three topologies span the §VI design space:
//!
//! * **local**  — per-rank node-local GPUs (the paper's GPU
//!   convention: zero-cost link, Figs. 4–10);
//! * **pooled** — one shared disaggregated RDU pool across the
//!   Infiniband link (Figs. 15/16), heterogeneous tile groups
//!   (4-tile + 2-tile, the allocator's natural shapes);
//! * **hybrid** — the hot MIR model stays on per-rank local GPUs
//!   while the long-tail per-material Hermit instances share the
//!   remote pool ("local vs pooled vs hybrid" — the coupling-topology
//!   axis of AI-coupled HPC workflows).
//!
//! Everything runs in virtual time on the calibrated analytic models,
//! so a fixed seed yields a byte-stable summary
//! (`rust/tests/campaign_golden.rs` pins it).  MIR uses the paper's
//! no-layernorm variant (Fig. 20) so both architectures execute the
//! same network.
//!
//! Besides the analytic sweep there is an **event mode**
//! ([`run_event_campaign`]): the same topology fleets driven by the
//! discrete-event simulator ([`crate::eventsim`]) across rank count ×
//! arrival process × dynamic-batching window, reporting full latency
//! distributions (p50/p99/p99.9, histograms, per-rank slowdown) —
//! `repro eventsim` on the command line.
//!
//! And a **cogsim mode** ([`run_cog_campaign`]): the *coupled*
//! application model ([`crate::eventsim::cogsim`]) swept over
//! topology × policy × rank count × models-per-rank × swap cost ×
//! overlap, reporting time-to-solution with its per-timestep
//! critical-path breakdown — `repro cogsim` on the command line.
//!
//! All three modes carry a **fabric knob** (`fabric_oversubs`): the
//! pooled/hybrid topologies' network is swept across leaf/spine
//! oversubscription factors (1:1 non-blocking up to 8:1).  The event
//! and cogsim modes route remote dispatches through the
//! contention-aware flow-level simulator ([`crate::fabric`]) — shared
//! uplinks, max-min fair share, swap traffic competing with inference
//! — while the analytic mode applies the closed-form worst-case
//! derate (pool link bandwidth divided by the oversubscription).
//! `repro fabric` runs the focused pooled-vs-local crossover sweep.

use crate::cluster::{Backend, BackendReport, Cluster, GpuBackend, Policy, RduBackend};
use crate::devices::{profiles, Api, Gpu, ModelProfile};
use crate::eventsim::{
    ArrivalProcess, Batching, CogSim, CogSimConfig, CogSummary, EventSim, EventSimConfig,
    EventSummary,
};
use crate::fabric::{FabricSpec, Topology as NetTopology};
use crate::netsim::Link;
use crate::rdu::RduApi;
use crate::util::json::Value;
use crate::util::stats;
use crate::workload::{HydraWorkload, MirWorkload};

use std::collections::BTreeMap;

use super::table::Table;

/// The three coupling topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    Local,
    Pooled,
    Hybrid,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Local, Topology::Pooled, Topology::Hybrid];

    pub fn label(&self) -> &'static str {
        match self {
            Topology::Local => "per-rank local GPUs",
            Topology::Pooled => "shared disaggregated RDU pool",
            Topology::Hybrid => "hybrid (MIR local, Hermit pooled)",
        }
    }

    /// Stable snake_case key for JSON artifacts.
    pub fn key(&self) -> &'static str {
        match self {
            Topology::Local => "local",
            Topology::Pooled => "pooled",
            Topology::Hybrid => "hybrid",
        }
    }

    /// Does this topology have backends behind the shared fabric?
    /// Local is all node-local: the oversubscription axis collapses
    /// to a single 1:1 cell there (no duplicate sweep cells).
    pub fn pays_the_link(&self) -> bool {
        !matches!(self, Topology::Local)
    }
}

// ----------------------------------------------- shared scaffolding
//
// The three campaign modes (analytic / event / cogsim) share their
// sweep-grid and JSON-emit skeleton; these helpers hold the single
// copy (previously ~3 hand-rolled repetitions of each).

/// The oversubscription cells a topology actually sweeps: the
/// configured list where the fabric exists, the single 1:1 cell on
/// the all-local topology.
fn oversubs_for(topology: Topology, oversubs: &[f64]) -> Vec<f64> {
    if topology.pays_the_link() {
        oversubs.to_vec()
    } else {
        vec![1.0]
    }
}

/// JSON array of stable keys (topologies, policies, ...).
fn key_array<T>(items: &[T], key: impl Fn(&T) -> String) -> Value {
    Value::Array(items.iter().map(|i| Value::String(key(i))).collect())
}

/// JSON array of numbers at fixed precision.
fn num_array(items: &[f64]) -> Value {
    Value::Array(items.iter().map(|&v| fixed3(v)).collect())
}

/// The root campaign document every mode emits: `{config, scenarios}`.
fn doc_json(config: Value, scenarios: Vec<Value>) -> Value {
    let mut root = BTreeMap::new();
    root.insert("config".to_string(), config);
    root.insert("scenarios".to_string(), Value::Array(scenarios));
    Value::Object(root)
}

/// One aligned table per topology over a sweep's cells: `x_of` labels
/// each cell, `series` extracts the numeric columns.  (The analytic
/// mode keeps its bespoke metric-per-column layout; the event and
/// cogsim sweeps share this cell-per-row shape.)
fn topology_tables<S>(
    title_prefix: &str,
    topologies: &[Topology],
    scenarios: &[S],
    topo_of: impl Fn(&S) -> Topology,
    x_of: impl Fn(&S) -> String,
    series: &[(&str, &dyn Fn(&S) -> f64)],
) -> Vec<Table> {
    topologies
        .iter()
        .map(|&topo| {
            let cells: Vec<&S> =
                scenarios.iter().filter(|s| topo_of(s) == topo).collect();
            let mut t = Table::new(
                format!("{title_prefix} — {} ({})", topo.key(), topo.label()),
                "cell",
            );
            t.set_x(cells.iter().map(|s| x_of(s)));
            for (name, extract) in series {
                t.add_series(*name, cells.iter().map(|s| extract(s)).collect());
            }
            t
        })
        .collect()
}

/// Fabric spec for an event/cogsim cell: the flow-level topology plus
/// the backend→accel endpoint map matching [`build_fleet`]'s layout.
/// `None` on the all-local topology (no shared links to model).
fn build_fabric_spec(topology: Topology, ranks: usize, oversub: f64) -> Option<FabricSpec> {
    match topology {
        Topology::Local => None,
        Topology::Pooled => Some(FabricSpec {
            topology: NetTopology::pooled(ranks, 2, oversub),
            accel_of_backend: vec![0, 1],
        }),
        Topology::Hybrid => Some(FabricSpec {
            topology: NetTopology::hybrid(ranks, 2, oversub),
            // GPU i sits in node i; the pool rides the fabric.
            accel_of_backend: (0..ranks).chain([ranks, ranks + 1]).collect(),
        }),
    }
}

/// Campaign knobs (defaults sized so the full 3×4 sweep runs in
/// milliseconds of wall time).
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Hydra zones per rank per timestep.
    pub zones_per_rank: usize,
    /// Per-material Hermit instances per rank.
    pub materials: usize,
    /// Simulated physics timesteps.
    pub timesteps: usize,
    /// Virtual seconds between timesteps (queues drain in between).
    pub step_period_s: f64,
    /// Base MIR mixed-zone count per rank per timestep.
    pub mir_base_zones: usize,
    /// Fabric oversubscription factors to sweep on topologies with
    /// pooled backends (the analytic mode applies the closed-form
    /// worst-case derate: pool link bandwidth ÷ oversubscription).
    pub fabric_oversubs: Vec<f64>,
    /// Workload seed (fixed seed → byte-stable summary).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            ranks: 4,
            zones_per_rank: 200,
            materials: 8,
            timesteps: 12,
            step_period_s: 0.02,
            mir_base_zones: 1024,
            fabric_oversubs: vec![1.0],
            seed: 42,
        }
    }
}

/// Latency/throughput summary for one workload within a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSummary {
    pub requests: u64,
    pub samples: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_link_overhead_s: f64,
    /// Samples over the scenario makespan.
    pub samples_per_s: f64,
}

impl WorkloadSummary {
    fn from_run(latencies: &[f64], link_overheads: &[f64], samples: u64, makespan_s: f64) -> Self {
        WorkloadSummary {
            requests: latencies.len() as u64,
            samples,
            mean_s: stats::mean(latencies),
            p50_s: stats::percentile(latencies, 50.0),
            p95_s: stats::percentile(latencies, 95.0),
            p99_s: stats::percentile(latencies, 99.0),
            mean_link_overhead_s: stats::mean(link_overheads),
            samples_per_s: if makespan_s > 0.0 { samples as f64 / makespan_s } else { 0.0 },
        }
    }
}

/// One (topology, policy, oversubscription) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub hydra: WorkloadSummary,
    pub mir: WorkloadSummary,
    pub makespan_s: f64,
    pub backends: Vec<BackendReport>,
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config: CampaignConfig,
    pub scenarios: Vec<ScenarioResult>,
}

impl CampaignResult {
    /// Look up the baseline cell of a (topology, policy) pair: the
    /// non-blocking 1:1 cell when it was swept, otherwise the first
    /// swept oversubscription (so the classic lookup stays total
    /// over any `fabric_oversubs` configuration).
    pub fn scenario(&self, topology: Topology, policy: Policy) -> &ScenarioResult {
        self.scenario_at(topology, policy, 1.0)
            .or_else(|| {
                self.scenarios
                    .iter()
                    .find(|s| s.topology == topology && s.policy == policy)
            })
            .expect("campaign ran every (topology, policy) cell")
    }

    /// Look up one cell at an explicit oversubscription factor.
    pub fn scenario_at(
        &self,
        topology: Topology,
        policy: Policy,
        oversub: f64,
    ) -> Option<&ScenarioResult> {
        self.scenarios
            .iter()
            .find(|s| s.topology == topology && s.policy == policy && s.oversub == oversub)
    }

    /// Deterministic JSON document (BTreeMap key order; values
    /// rounded to fixed precision so the rendering is byte-stable).
    pub fn to_json(&self) -> Value {
        doc_json(
            config_json(&self.config),
            self.scenarios.iter().map(scenario_json).collect(),
        )
    }

    /// One aligned table per topology (rows: policy; columns: key
    /// latency/throughput figures).
    pub fn tables(&self) -> Vec<Table> {
        Topology::ALL
            .iter()
            .map(|&topo| {
                let mut t = Table::new(
                    format!("Campaign — {} ({})", topo.key(), topo.label()),
                    "metric",
                );
                t.set_x([
                    "hydra_p50_us",
                    "hydra_p99_us",
                    "hydra_Msamples_per_s",
                    "mir_p50_us",
                    "mir_p99_us",
                ]);
                for policy in Policy::ALL {
                    let s = self.scenario(topo, policy);
                    t.add_series(
                        policy.key(),
                        vec![
                            s.hydra.p50_s * 1e6,
                            s.hydra.p99_s * 1e6,
                            s.hydra.samples_per_s / 1e6,
                            s.mir.p50_s * 1e6,
                            s.mir.p99_s * 1e6,
                        ],
                    );
                }
                t
            })
            .collect()
    }
}

/// Tiering: which backend indices serve which model class.
struct Tiering {
    hermit: Vec<usize>,
    mir: Vec<usize>,
}

/// Build a topology's backend fleet + tiering (shared by the analytic
/// cluster sweep and the event-sim mode).
fn build_fleet(topology: Topology, ranks: usize, pool_link: &Link) -> (Vec<Box<dyn Backend>>, Tiering) {
    let local_gpu = |r: usize| -> Box<dyn Backend> {
        Box::new(GpuBackend::node_local(
            format!("gpu/rank{r}"),
            Gpu::a100(),
            Api::TrtCudaGraphs,
        ))
    };
    // The pool is deliberately heterogeneous — a full 4-tile group on
    // the optimised C++ stack next to a half-provisioned 2-tile group
    // still on the naive Python stack (the allocator's natural
    // shapes, Fig. 13's API spread): state-blind policies pay for not
    // seeing the difference.
    let pool = |start: usize| -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::with_link(
                format!("rdu/pool{start}"),
                4,
                RduApi::CppOptimized,
                pool_link.clone(),
            )),
            Box::new(RduBackend::with_link(
                format!("rdu/pool{}", start + 1),
                2,
                RduApi::Python,
                pool_link.clone(),
            )),
        ]
    };

    match topology {
        Topology::Local => {
            let backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let all: Vec<usize> = (0..backends.len()).collect();
            (backends, Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Pooled => {
            let backends = pool(0);
            let all: Vec<usize> = (0..backends.len()).collect();
            (backends, Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Hybrid => {
            let mut backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let gpu_idx: Vec<usize> = (0..backends.len()).collect();
            backends.extend(pool(0));
            let pool_idx: Vec<usize> = (gpu_idx.len()..backends.len()).collect();
            (backends, Tiering { hermit: pool_idx, mir: gpu_idx })
        }
    }
}

/// Build a topology's routed cluster + tiering.
fn build_cluster(
    topology: Topology,
    ranks: usize,
    policy: Policy,
    pool_link: &Link,
) -> (Cluster, Tiering) {
    let (backends, tier) = build_fleet(topology, ranks, pool_link);
    (Cluster::new(backends, policy), tier)
}

/// Campaign model mapping: Hermit requests use the Hermit profile;
/// MIR requests use the Fig-20 no-layernorm variant so GPU and RDU
/// backends execute the same network.
fn profile_for(model: &str) -> ModelProfile {
    if model.starts_with("mir") {
        profiles::mir_noln()
    } else {
        profiles::hermit()
    }
}

/// Run one (topology, policy) scenario at 1:1 oversubscription.
pub fn run_scenario(topology: Topology, policy: Policy, cfg: &CampaignConfig) -> ScenarioResult {
    run_scenario_with_link(topology, policy, cfg, &Link::infiniband_cx6())
}

/// Worst-case closed-form fabric derate for the analytic mode: every
/// remote request is assumed to find the oversubscribed uplink fully
/// contended, i.e. the pool link's effective bandwidth divides by the
/// oversubscription factor.  (The event/cogsim modes model the real
/// time-varying sharing through [`crate::fabric`].)
fn derated_link(link: &Link, oversub: f64) -> Link {
    assert!(oversub >= 1.0 && oversub.is_finite());
    let mut l = link.clone();
    if l.eff_bandwidth.is_finite() {
        l.eff_bandwidth = l.eff_bandwidth / oversub;
    }
    l
}

/// Run one analytic cell at an explicit oversubscription factor.
pub fn run_scenario_at(
    topology: Topology,
    policy: Policy,
    oversub: f64,
    cfg: &CampaignConfig,
) -> ScenarioResult {
    let link = derated_link(&Link::infiniband_cx6(), oversub);
    let mut s = run_scenario_with_link(topology, policy, cfg, &link);
    s.oversub = oversub;
    s
}

/// As [`run_scenario`], with an explicit pool link — the link
/// ablation behind the Fig-15/16 anchor test (swap the Infiniband
/// model for [`Link::local`] to measure the pure remote overhead).
pub fn run_scenario_with_link(
    topology: Topology,
    policy: Policy,
    cfg: &CampaignConfig,
    pool_link: &Link,
) -> ScenarioResult {
    let (mut cluster, tier) = build_cluster(topology, cfg.ranks, policy, pool_link);

    let hydra = HydraWorkload {
        ranks: cfg.ranks,
        zones_per_rank: cfg.zones_per_rank,
        materials: cfg.materials,
        inferences_per_zone: (2, 3),
        seed: cfg.seed,
    };
    let mir = MirWorkload {
        ranks: cfg.ranks,
        base_zones: cfg.mir_base_zones,
        variation: 0.4,
        seed: cfg.seed ^ 0x5EED,
    };
    let hermit_profile = profile_for("hermit");
    let mir_profile = profile_for("mir");

    let mut hydra_lat = Vec::new();
    let mut hydra_link = Vec::new();
    let mut hydra_samples = 0u64;
    let mut mir_lat = Vec::new();
    let mut mir_link = Vec::new();
    let mut mir_samples = 0u64;

    for t in 0..cfg.timesteps {
        cluster.advance_to(t as f64 * cfg.step_period_s);
        for req in hydra.timestep(t) {
            let routed =
                cluster.submit_among(&tier.hermit, &req.model, &hermit_profile, req.samples);
            hydra_lat.push(routed.latency_s);
            hydra_link.push(routed.link_overhead_s);
            hydra_samples += req.samples as u64;
        }
        for req in mir.timestep(t) {
            let routed = cluster.submit_among(&tier.mir, &req.model, &mir_profile, req.samples);
            mir_lat.push(routed.latency_s);
            mir_link.push(routed.link_overhead_s);
            mir_samples += req.samples as u64;
        }
    }

    let makespan_s = cluster.makespan_s();
    ScenarioResult {
        topology,
        policy,
        oversub: 1.0,
        hydra: WorkloadSummary::from_run(&hydra_lat, &hydra_link, hydra_samples, makespan_s),
        mir: WorkloadSummary::from_run(&mir_lat, &mir_link, mir_samples, makespan_s),
        makespan_s,
        backends: cluster.report(),
    }
}

/// Run the full sweep: every topology under every routing policy,
/// across the fabric oversubscription axis (all-local topologies run
/// the single 1:1 cell — no fabric to derate).
pub fn run_campaign(cfg: &CampaignConfig) -> CampaignResult {
    let mut scenarios = Vec::new();
    for topology in Topology::ALL {
        for policy in Policy::ALL {
            for oversub in oversubs_for(topology, &cfg.fabric_oversubs) {
                scenarios.push(run_scenario_at(topology, policy, oversub, cfg));
            }
        }
    }
    CampaignResult { config: cfg.clone(), scenarios }
}

// ------------------------------------------------------- event mode

/// Event-mode campaign knobs: the discrete-event simulator
/// ([`crate::eventsim`]) swept over topology × policy × rank count ×
/// arrival process × batching window.  Unlike the analytic sweep,
/// this resolves *when* requests collide — the queueing behaviour of
/// bursty multi-rank arrivals that the closed-form cluster cannot
/// express.
#[derive(Debug, Clone)]
pub struct EventCampaignConfig {
    pub topologies: Vec<Topology>,
    pub policies: Vec<Policy>,
    /// MPI rank counts to sweep (local topology gets one GPU per rank).
    pub rank_counts: Vec<usize>,
    pub arrivals: Vec<ArrivalProcess>,
    /// Dynamic-batching windows, µs; `0` disables batching.
    pub windows_us: Vec<f64>,
    /// Sample cap per coalesced batch.
    pub max_batch: usize,
    /// Per-material Hermit instances.
    pub materials: usize,
    /// Samples per request, uniform inclusive (paper: 2–3 per zone).
    pub samples_per_request: (usize, usize),
    /// Synchronized mode: requests per rank per burst.
    pub requests_per_burst: usize,
    /// Synchronized mode: emit one MIR request per rank every k-th
    /// burst (0 = hermit-only).
    pub mir_every: usize,
    pub mir_samples: usize,
    /// Fabric oversubscription factors to sweep; pooled/hybrid cells
    /// route remote dispatches through the flow-level
    /// [`crate::fabric`] simulator at each factor.
    pub fabric_oversubs: Vec<f64>,
    /// Arrival generators stop here; in-flight work drains.
    pub horizon_s: f64,
    pub seed: u64,
}

impl Default for EventCampaignConfig {
    fn default() -> Self {
        EventCampaignConfig {
            // Hybrid needs MIR traffic to differ from Pooled; the
            // default event sweep studies the bursty in-the-loop
            // Hermit regime, so it covers the two endpoints.
            topologies: vec![Topology::Local, Topology::Pooled],
            policies: vec![Policy::RoundRobin, Policy::LatencyAware],
            rank_counts: vec![4, 64],
            arrivals: vec![
                ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
                ArrivalProcess::Poisson { rate_per_rank: 800.0 },
                ArrivalProcess::ClosedLoop { think_s: 2e-3 },
            ],
            windows_us: vec![0.0, 200.0],
            max_batch: 256,
            materials: 8,
            samples_per_request: (2, 3),
            requests_per_burst: 6,
            mir_every: 0,
            mir_samples: 512,
            fabric_oversubs: vec![1.0, 4.0],
            horizon_s: 0.2,
            seed: 42,
        }
    }
}

/// One (topology, policy, arrival, ranks, window, oversub) cell.
#[derive(Debug, Clone)]
pub struct EventScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    pub arrival: ArrivalProcess,
    pub ranks: usize,
    pub window_us: f64,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub summary: EventSummary,
}

/// The full event-mode sweep.
#[derive(Debug, Clone)]
pub struct EventCampaignResult {
    pub config: EventCampaignConfig,
    pub scenarios: Vec<EventScenarioResult>,
}

impl EventCampaignResult {
    /// Look up one cell (`arrival_key` as in [`ArrivalProcess::key`]).
    pub fn scenario(
        &self,
        topology: Topology,
        policy: Policy,
        arrival_key: &str,
        ranks: usize,
        window_us: f64,
        oversub: f64,
    ) -> Option<&EventScenarioResult> {
        self.scenarios.iter().find(|s| {
            s.topology == topology
                && s.policy == policy
                && s.arrival.key() == arrival_key
                && s.ranks == ranks
                && s.window_us == window_us
                && s.oversub == oversub
        })
    }

    /// Deterministic JSON document (BTreeMap key order; fixed
    /// precision), golden-pinned by `rust/tests/campaign_golden.rs`.
    pub fn to_json(&self) -> Value {
        doc_json(
            event_config_json(&self.config),
            self.scenarios.iter().map(event_scenario_json).collect(),
        )
    }

    /// One aligned table per topology; one row per swept cell.
    pub fn tables(&self) -> Vec<Table> {
        topology_tables(
            "Event campaign",
            &self.config.topologies,
            &self.scenarios,
            |s: &EventScenarioResult| s.topology,
            |s| {
                format!(
                    "{}/{}/r{}/w{}/o{}",
                    s.policy.key(),
                    s.arrival.key(),
                    s.ranks,
                    s.window_us,
                    s.oversub
                )
            },
            &[
                ("p50_us", &|s: &EventScenarioResult| s.summary.latency.p50_s * 1e6),
                ("p99_us", &|s: &EventScenarioResult| s.summary.latency.p99_s * 1e6),
                ("p999_us", &|s: &EventScenarioResult| s.summary.latency.p999_s * 1e6),
                ("mean_batch", &|s: &EventScenarioResult| s.summary.mean_batch_samples),
                ("contention_us", &|s: &EventScenarioResult| {
                    s.summary.mean_contention_s * 1e6
                }),
                ("slowdown", &|s: &EventScenarioResult| s.summary.slowdown_max),
            ],
        )
    }
}

/// Run one event-mode cell.  Pooled/hybrid topologies route remote
/// dispatches through the flow-level fabric at `oversub`; the
/// all-local topology has no shared links.
pub fn run_event_scenario(
    topology: Topology,
    policy: Policy,
    arrival: ArrivalProcess,
    ranks: usize,
    window_us: f64,
    oversub: f64,
    cfg: &EventCampaignConfig,
) -> EventScenarioResult {
    let (backends, tier) = build_fleet(topology, ranks, &Link::infiniband_cx6());
    let sim_cfg = EventSimConfig {
        ranks,
        materials: cfg.materials,
        samples_per_request: cfg.samples_per_request,
        requests_per_burst: cfg.requests_per_burst,
        mir_every: cfg.mir_every,
        mir_samples: cfg.mir_samples,
        arrival,
        batching: if window_us > 0.0 {
            Batching::Window { window_s: window_us * 1e-6, max_batch: cfg.max_batch }
        } else {
            Batching::Off
        },
        horizon_s: cfg.horizon_s,
        seed: cfg.seed,
    };
    let mut sim = match build_fabric_spec(topology, ranks, oversub) {
        Some(spec) => {
            EventSim::with_fabric(backends, policy, sim_cfg, tier.hermit, tier.mir, spec)
        }
        None => EventSim::with_tiers(backends, policy, sim_cfg, tier.hermit, tier.mir),
    };
    sim.run_to_completion();
    EventScenarioResult {
        topology,
        policy,
        arrival,
        ranks,
        window_us,
        oversub,
        summary: sim.summary(),
    }
}

/// Run the full event-mode sweep.
pub fn run_event_campaign(cfg: &EventCampaignConfig) -> EventCampaignResult {
    let mut scenarios = Vec::new();
    for &topology in &cfg.topologies {
        for &policy in &cfg.policies {
            for &ranks in &cfg.rank_counts {
                for &arrival in &cfg.arrivals {
                    for &window_us in &cfg.windows_us {
                        for oversub in oversubs_for(topology, &cfg.fabric_oversubs) {
                            scenarios.push(run_event_scenario(
                                topology, policy, arrival, ranks, window_us, oversub, cfg,
                            ));
                        }
                    }
                }
            }
        }
    }
    EventCampaignResult { config: cfg.clone(), scenarios }
}

// ------------------------------------------------------ cogsim mode

/// Coupled-campaign knobs: the CogSim application model
/// ([`crate::eventsim::cogsim`]) swept over topology × policy × rank
/// count × models-per-rank × swap cost × overlap.  This is the only
/// mode that reports the paper's real figure of merit —
/// time-to-solution — because it is the only one where inference
/// latency feeds back into when the next timestep's requests exist.
#[derive(Debug, Clone)]
pub struct CogCampaignConfig {
    pub topologies: Vec<Topology>,
    pub policies: Vec<Policy>,
    /// MPI rank counts (local topology gets one GPU per rank).
    pub rank_counts: Vec<usize>,
    /// Target-model counts per rank (M per-material Hermit instances).
    pub models_per_rank: Vec<usize>,
    /// Residency swap costs to sweep, seconds.
    pub swap_costs_s: Vec<f64>,
    /// Compute/inference overlap fractions to sweep.
    pub overlaps: Vec<f64>,
    /// Bulk-synchronous timesteps per run.
    pub timesteps: usize,
    /// Physics compute per rank per timestep, seconds.
    pub compute_s: f64,
    /// In-the-loop requests per rank per timestep (K).
    pub requests_per_step: usize,
    /// Samples per request, uniform inclusive.
    pub samples_per_request: (usize, usize),
    /// Every `mir_every`-th step adds one MIR request per rank.
    pub mir_every: usize,
    pub mir_samples: usize,
    /// Models resident per backend (LRU).
    pub residency_slots: usize,
    /// Router batching window, µs; 0 disables batching.
    pub window_us: f64,
    pub max_batch: usize,
    /// Fabric oversubscription factors to sweep; pooled/hybrid cells
    /// route remote dispatches (and residency-swap weight transfers)
    /// through the flow-level [`crate::fabric`] simulator.
    pub fabric_oversubs: Vec<f64>,
    pub seed: u64,
}

impl Default for CogCampaignConfig {
    fn default() -> Self {
        CogCampaignConfig {
            // The two coupling endpoints; hybrid needs MIR cadence
            // (set mir_every > 0) to differ from pooled.
            topologies: vec![Topology::Local, Topology::Pooled],
            policies: Policy::ALL.to_vec(),
            // 4 ranks: the pool's home turf; 32: the burst regime
            // where sharing 2 accelerators (and their fabric) hurts
            rank_counts: vec![4, 32],
            models_per_rank: vec![8],
            // free swaps vs swaps far above the small-batch service
            // time — the regime where affinity routing must win
            swap_costs_s: vec![0.0, 2e-3],
            overlaps: vec![0.0],
            timesteps: 8,
            compute_s: 2e-3,
            requests_per_step: 6,
            samples_per_request: (2, 3),
            mir_every: 0,
            mir_samples: 512,
            residency_slots: 4,
            window_us: 0.0,
            max_batch: 256,
            // the contention axis of the acceptance headline: 1:1
            // non-blocking through 8:1 starved
            fabric_oversubs: vec![1.0, 2.0, 4.0, 8.0],
            seed: 42,
        }
    }
}

/// One (topology, policy, ranks, models, swap, overlap, oversub) cell.
#[derive(Debug, Clone)]
pub struct CogScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    pub ranks: usize,
    pub models: usize,
    pub swap_s: f64,
    pub overlap: f64,
    /// Fabric oversubscription of this cell (1.0 = non-blocking).
    pub oversub: f64,
    pub summary: CogSummary,
}

/// The full coupled sweep.
#[derive(Debug, Clone)]
pub struct CogCampaignResult {
    pub config: CogCampaignConfig,
    pub scenarios: Vec<CogScenarioResult>,
}

impl CogCampaignResult {
    /// Look up one cell.
    #[allow(clippy::too_many_arguments)]
    pub fn scenario(
        &self,
        topology: Topology,
        policy: Policy,
        ranks: usize,
        models: usize,
        swap_s: f64,
        overlap: f64,
        oversub: f64,
    ) -> Option<&CogScenarioResult> {
        self.scenarios.iter().find(|s| {
            s.topology == topology
                && s.policy == policy
                && s.ranks == ranks
                && s.models == models
                && s.swap_s == swap_s
                && s.overlap == overlap
                && s.oversub == oversub
        })
    }

    /// Deterministic JSON document (BTreeMap key order; fixed
    /// precision), golden-pinned by `rust/tests/campaign_golden.rs`.
    pub fn to_json(&self) -> Value {
        doc_json(
            cog_config_json(&self.config),
            self.scenarios.iter().map(cog_scenario_json).collect(),
        )
    }

    /// One aligned table per topology; one row per swept cell.
    pub fn tables(&self) -> Vec<Table> {
        topology_tables(
            "CogSim campaign",
            &self.config.topologies,
            &self.scenarios,
            |s: &CogScenarioResult| s.topology,
            |s| {
                format!(
                    "{}/r{}/m{}/sw{}/ov{}/o{}",
                    s.policy.key(),
                    s.ranks,
                    s.models,
                    s.swap_s * 1e6,
                    s.overlap,
                    s.oversub
                )
            },
            &[
                ("tts_ms", &|s: &CogScenarioResult| s.summary.time_to_solution_s * 1e3),
                ("compute_ms", &|s: &CogScenarioResult| s.summary.total_compute_s * 1e3),
                ("queue_ms", &|s: &CogScenarioResult| s.summary.total_queue_s * 1e3),
                ("swap_ms", &|s: &CogScenarioResult| s.summary.total_swap_s * 1e3),
                ("network_ms", &|s: &CogScenarioResult| s.summary.total_network_s * 1e3),
                ("contention_ms", &|s: &CogScenarioResult| {
                    s.summary.total_contention_s * 1e3
                }),
                ("service_ms", &|s: &CogScenarioResult| s.summary.total_service_s * 1e3),
                ("swaps", &|s: &CogScenarioResult| s.summary.swaps as f64),
                ("spread_us", &|s: &CogScenarioResult| s.summary.max_spread_s * 1e6),
            ],
        )
    }
}

/// Run one coupled cell.  Pooled/hybrid topologies route remote
/// dispatches and residency swaps through the flow-level fabric at
/// `oversub`; the all-local topology has no shared links.
#[allow(clippy::too_many_arguments)]
pub fn run_cog_scenario(
    topology: Topology,
    policy: Policy,
    ranks: usize,
    models: usize,
    swap_s: f64,
    overlap: f64,
    oversub: f64,
    cfg: &CogCampaignConfig,
) -> CogScenarioResult {
    let (backends, tier) = build_fleet(topology, ranks, &Link::infiniband_cx6());
    let sim_cfg = CogSimConfig {
        ranks,
        timesteps: cfg.timesteps,
        compute_s: cfg.compute_s,
        compute_jitter_s: 0.0,
        requests_per_step: cfg.requests_per_step,
        models,
        samples_per_request: cfg.samples_per_request,
        mir_every: cfg.mir_every,
        mir_samples: cfg.mir_samples,
        overlap,
        swap_s,
        residency_slots: cfg.residency_slots,
        batching: if cfg.window_us > 0.0 {
            Batching::Window { window_s: cfg.window_us * 1e-6, max_batch: cfg.max_batch }
        } else {
            Batching::Off
        },
        seed: cfg.seed,
    };
    let mut sim = match build_fabric_spec(topology, ranks, oversub) {
        Some(spec) => {
            CogSim::with_fabric(backends, policy, sim_cfg, tier.hermit, tier.mir, spec)
        }
        None => CogSim::with_tiers(backends, policy, sim_cfg, tier.hermit, tier.mir),
    };
    sim.run_to_completion();
    CogScenarioResult {
        topology,
        policy,
        ranks,
        models,
        swap_s,
        overlap,
        oversub,
        summary: sim.summary(),
    }
}

/// Run the full coupled sweep.
pub fn run_cog_campaign(cfg: &CogCampaignConfig) -> CogCampaignResult {
    let mut scenarios = Vec::new();
    for &topology in &cfg.topologies {
        for &policy in &cfg.policies {
            for &ranks in &cfg.rank_counts {
                for &models in &cfg.models_per_rank {
                    for &swap_s in &cfg.swap_costs_s {
                        for &overlap in &cfg.overlaps {
                            for oversub in oversubs_for(topology, &cfg.fabric_oversubs) {
                                scenarios.push(run_cog_scenario(
                                    topology, policy, ranks, models, swap_s, overlap, oversub,
                                    cfg,
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
    CogCampaignResult { config: cfg.clone(), scenarios }
}

// ------------------------------------------------------------- JSON

/// Microseconds at fixed 3-decimal precision (byte-stable rendering).
fn us(seconds: f64) -> Value {
    Value::Number((seconds * 1e9).round() / 1e3)
}

/// A plain number at fixed 3-decimal precision.
fn fixed3(v: f64) -> Value {
    Value::Number((v * 1e3).round() / 1e3)
}

fn count(v: u64) -> Value {
    Value::Number(v as f64)
}

fn config_json(cfg: &CampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(cfg.ranks as u64));
    m.insert("zones_per_rank".to_string(), count(cfg.zones_per_rank as u64));
    m.insert("materials".to_string(), count(cfg.materials as u64));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("step_period_us".to_string(), us(cfg.step_period_s));
    m.insert("mir_base_zones".to_string(), count(cfg.mir_base_zones as u64));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn workload_json(w: &WorkloadSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("requests".to_string(), count(w.requests));
    m.insert("samples".to_string(), count(w.samples));
    m.insert("mean_us".to_string(), us(w.mean_s));
    m.insert("p50_us".to_string(), us(w.p50_s));
    m.insert("p95_us".to_string(), us(w.p95_s));
    m.insert("p99_us".to_string(), us(w.p99_s));
    m.insert("mean_link_overhead_us".to_string(), us(w.mean_link_overhead_s));
    m.insert("samples_per_s".to_string(), fixed3(w.samples_per_s));
    Value::Object(m)
}

fn scenario_json(s: &ScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    m.insert("hydra".to_string(), workload_json(&s.hydra));
    m.insert("mir".to_string(), workload_json(&s.mir));
    m.insert("makespan_us".to_string(), us(s.makespan_s));
    let makespan = s.makespan_s.max(f64::MIN_POSITIVE);
    m.insert(
        "backends".to_string(),
        Value::Array(
            s.backends
                .iter()
                .map(|b| {
                    let mut bm = BTreeMap::new();
                    bm.insert("name".to_string(), Value::String(b.name.clone()));
                    bm.insert("requests".to_string(), count(b.requests));
                    bm.insert("samples".to_string(), count(b.samples));
                    bm.insert("busy_us".to_string(), us(b.busy_s));
                    bm.insert(
                        "utilization".to_string(),
                        Value::Number((b.busy_s / makespan * 1e6).round() / 1e6),
                    );
                    Value::Object(bm)
                })
                .collect(),
        ),
    );
    Value::Object(m)
}

// -------------------------------------------------- event-mode JSON

fn arrival_json(a: &ArrivalProcess) -> Value {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Value::String(a.key().to_string()));
    match *a {
        ArrivalProcess::Synchronized { period_s, jitter_s } => {
            m.insert("period_us".to_string(), us(period_s));
            m.insert("jitter_us".to_string(), us(jitter_s));
        }
        ArrivalProcess::Poisson { rate_per_rank } => {
            m.insert("rate_per_rank".to_string(), fixed3(rate_per_rank));
        }
        ArrivalProcess::ClosedLoop { think_s } => {
            m.insert("think_us".to_string(), us(think_s));
        }
    }
    Value::Object(m)
}

fn event_config_json(cfg: &EventCampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topologies".to_string(), key_array(&cfg.topologies, |t| t.key().to_string()));
    m.insert("policies".to_string(), key_array(&cfg.policies, |p| p.key().to_string()));
    m.insert(
        "rank_counts".to_string(),
        Value::Array(cfg.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "arrivals".to_string(),
        Value::Array(cfg.arrivals.iter().map(arrival_json).collect()),
    );
    m.insert("windows_us".to_string(), num_array(&cfg.windows_us));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("max_batch".to_string(), count(cfg.max_batch as u64));
    m.insert("materials".to_string(), count(cfg.materials as u64));
    m.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(cfg.samples_per_request.0 as u64),
            count(cfg.samples_per_request.1 as u64),
        ]),
    );
    m.insert("requests_per_burst".to_string(), count(cfg.requests_per_burst as u64));
    m.insert("mir_every".to_string(), count(cfg.mir_every as u64));
    m.insert("mir_samples".to_string(), count(cfg.mir_samples as u64));
    m.insert("horizon_us".to_string(), us(cfg.horizon_s));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn event_summary_json(s: &EventSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("requests".to_string(), count(s.requests));
    m.insert("samples".to_string(), count(s.samples));
    m.insert("batches".to_string(), count(s.batches));
    m.insert("mean_batch_samples".to_string(), fixed3(s.mean_batch_samples));
    m.insert("mean_us".to_string(), us(s.latency.mean_s));
    m.insert("p50_us".to_string(), us(s.latency.p50_s));
    m.insert("p90_us".to_string(), us(s.latency.p90_s));
    m.insert("p99_us".to_string(), us(s.latency.p99_s));
    m.insert("p999_us".to_string(), us(s.latency.p999_s));
    m.insert("max_us".to_string(), us(s.latency.max_s));
    m.insert("mean_link_overhead_us".to_string(), us(s.mean_link_overhead_s));
    m.insert("mean_contention_us".to_string(), us(s.mean_contention_s));
    m.insert("samples_per_s".to_string(), fixed3(s.samples_per_s));
    m.insert("makespan_us".to_string(), us(s.makespan_s));
    m.insert("slowdown_max".to_string(), fixed3(s.slowdown_max));
    m.insert(
        "histogram".to_string(),
        Value::Array(
            s.latency
                .histogram
                .iter()
                .filter(|(_, c)| *c > 0)
                .map(|&(le_us, c)| {
                    let mut bm = BTreeMap::new();
                    bm.insert("le_us".to_string(), Value::Number(le_us));
                    bm.insert("count".to_string(), count(c));
                    Value::Object(bm)
                })
                .collect(),
        ),
    );
    m.insert("overflow".to_string(), count(s.latency.overflow));
    Value::Object(m)
}

fn event_scenario_json(s: &EventScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("arrival".to_string(), Value::String(s.arrival.key().to_string()));
    m.insert("ranks".to_string(), count(s.ranks as u64));
    m.insert("window_us".to_string(), fixed3(s.window_us));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    m.insert("summary".to_string(), event_summary_json(&s.summary));
    Value::Object(m)
}

// -------------------------------------------------- cogsim-mode JSON

fn cog_config_json(cfg: &CogCampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topologies".to_string(), key_array(&cfg.topologies, |t| t.key().to_string()));
    m.insert("policies".to_string(), key_array(&cfg.policies, |p| p.key().to_string()));
    m.insert(
        "rank_counts".to_string(),
        Value::Array(cfg.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "models_per_rank".to_string(),
        Value::Array(cfg.models_per_rank.iter().map(|&m| count(m as u64)).collect()),
    );
    m.insert(
        "swap_costs_us".to_string(),
        Value::Array(cfg.swap_costs_s.iter().map(|&s| us(s)).collect()),
    );
    m.insert("overlaps".to_string(), num_array(&cfg.overlaps));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("compute_us".to_string(), us(cfg.compute_s));
    m.insert("requests_per_step".to_string(), count(cfg.requests_per_step as u64));
    m.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(cfg.samples_per_request.0 as u64),
            count(cfg.samples_per_request.1 as u64),
        ]),
    );
    m.insert("mir_every".to_string(), count(cfg.mir_every as u64));
    m.insert("mir_samples".to_string(), count(cfg.mir_samples as u64));
    m.insert("residency_slots".to_string(), count(cfg.residency_slots as u64));
    m.insert("window_us".to_string(), fixed3(cfg.window_us));
    m.insert("max_batch".to_string(), count(cfg.max_batch as u64));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn cog_summary_json(s: &CogSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(s.ranks));
    m.insert("timesteps".to_string(), count(s.timesteps));
    m.insert("requests".to_string(), count(s.requests));
    m.insert("samples".to_string(), count(s.samples));
    m.insert("batches".to_string(), count(s.batches));
    m.insert("time_to_solution_us".to_string(), us(s.time_to_solution_s));
    m.insert("mean_step_us".to_string(), us(s.mean_step_s));
    m.insert("total_compute_us".to_string(), us(s.total_compute_s));
    m.insert("total_queue_us".to_string(), us(s.total_queue_s));
    m.insert("total_swap_us".to_string(), us(s.total_swap_s));
    m.insert("total_network_us".to_string(), us(s.total_network_s));
    m.insert("total_contention_us".to_string(), us(s.total_contention_s));
    m.insert("total_service_us".to_string(), us(s.total_service_s));
    m.insert("swaps".to_string(), count(s.swaps));
    m.insert("swap_time_us".to_string(), us(s.swap_time_s));
    m.insert("max_spread_us".to_string(), us(s.max_spread_s));
    m.insert("request_p50_us".to_string(), us(s.latency.p50_s));
    m.insert("request_p99_us".to_string(), us(s.latency.p99_s));
    m.insert(
        "straggler_counts".to_string(),
        Value::Array(s.straggler_counts.iter().map(|&c| count(c)).collect()),
    );
    m.insert(
        "steps".to_string(),
        Value::Array(
            s.steps
                .iter()
                .map(|st| {
                    let mut sm = BTreeMap::new();
                    sm.insert("step".to_string(), count(st.step as u64));
                    sm.insert("duration_us".to_string(), us(st.duration_s()));
                    sm.insert("straggler".to_string(), count(st.straggler as u64));
                    sm.insert("compute_us".to_string(), us(st.compute_s));
                    sm.insert("queue_us".to_string(), us(st.queue_s));
                    sm.insert("swap_us".to_string(), us(st.swap_s));
                    sm.insert("network_us".to_string(), us(st.network_s));
                    sm.insert("contention_us".to_string(), us(st.contention_s));
                    sm.insert("service_us".to_string(), us(st.service_s));
                    sm.insert("spread_us".to_string(), us(st.spread_s));
                    Value::Object(sm)
                })
                .collect(),
        ),
    );
    Value::Object(m)
}

fn cog_scenario_json(s: &CogScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("ranks".to_string(), count(s.ranks as u64));
    m.insert("models".to_string(), count(s.models as u64));
    m.insert("swap_us".to_string(), us(s.swap_s));
    m.insert("overlap".to_string(), fixed3(s.overlap));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    m.insert("summary".to_string(), cog_summary_json(&s.summary));
    Value::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> CampaignConfig {
        CampaignConfig { timesteps: 4, ..Default::default() }
    }

    #[test]
    fn campaign_covers_every_cell() {
        let result = run_campaign(&quick_cfg());
        assert_eq!(result.scenarios.len(), Topology::ALL.len() * Policy::ALL.len());
        for topo in Topology::ALL {
            for policy in Policy::ALL {
                let s = result.scenario(topo, policy);
                assert!(s.hydra.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.mir.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.makespan_s > 0.0);
            }
        }
    }

    #[test]
    fn scenarios_conserve_samples() {
        // every scenario of a sweep sees the same workload; each must
        // route exactly the submitted sample volume
        let result = run_campaign(&quick_cfg());
        let expect_hydra = result.scenarios[0].hydra.samples;
        let expect_mir = result.scenarios[0].mir.samples;
        assert!(expect_hydra > 0 && expect_mir > 0);
        for s in &result.scenarios {
            assert_eq!(s.hydra.samples, expect_hydra, "{:?}/{:?}", s.topology, s.policy);
            assert_eq!(s.mir.samples, expect_mir);
            let routed: u64 = s.backends.iter().map(|b| b.samples).sum();
            assert_eq!(routed, expect_hydra + expect_mir);
        }
    }

    #[test]
    fn local_topology_has_zero_link_overhead() {
        let s = run_scenario(Topology::Local, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.hydra.mean_link_overhead_s, 0.0);
        assert_eq!(s.mir.mean_link_overhead_s, 0.0);
    }

    #[test]
    fn pooled_topology_pays_the_link() {
        let s = run_scenario(Topology::Pooled, Policy::LatencyAware, &quick_cfg());
        assert!(s.hydra.mean_link_overhead_s > 0.0);
        // MIR payloads (2×2304 els/sample) dwarf Hermit's 42+30
        assert!(s.mir.mean_link_overhead_s > s.hydra.mean_link_overhead_s);
    }

    #[test]
    fn hybrid_keeps_mir_local() {
        let s = run_scenario(Topology::Hybrid, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.mir.mean_link_overhead_s, 0.0, "hot model must stay local");
        assert!(s.hydra.mean_link_overhead_s > 0.0, "long tail rides the link");
        // GPU backends saw only MIR traffic, the pool only Hermit
        let gpu_requests: u64 = s
            .backends
            .iter()
            .filter(|b| b.name.starts_with("gpu/"))
            .map(|b| b.requests)
            .sum();
        assert_eq!(gpu_requests, s.mir.requests);
    }

    #[test]
    fn json_is_deterministic() {
        let cfg = quick_cfg();
        let a = crate::util::json::write(&run_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_campaign(&cfg).to_json());
        assert_eq!(a, b);
        // and parses back
        assert!(crate::util::json::parse(&a).is_ok());
        assert!(a.contains("\"topology\":\"hybrid\""), "{}", &a[..200.min(a.len())]);
    }

    // ------------------------------------------------- event mode

    fn quick_event_cfg() -> EventCampaignConfig {
        EventCampaignConfig {
            rank_counts: vec![4],
            horizon_s: 0.05,
            ..Default::default()
        }
    }

    #[test]
    fn event_campaign_covers_every_cell() {
        let cfg = quick_event_cfg();
        let result = run_event_campaign(&cfg);
        let cells: usize = cfg
            .topologies
            .iter()
            .map(|&t| {
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.arrivals.len()
                    * cfg.windows_us.len()
                    * oversubs_for(t, &cfg.fabric_oversubs).len()
            })
            .sum();
        assert_eq!(result.scenarios.len(), cells);
        for s in &result.scenarios {
            assert!(s.summary.requests > 0, "{:?}/{:?}", s.topology, s.policy);
            assert!(s.summary.latency.p50_s > 0.0);
            assert!(s.summary.latency.p999_s >= s.summary.latency.p99_s);
        }
        // lookup works for an arbitrary cell; the local topology
        // collapses the oversubscription axis to the single 1:1 cell
        assert!(result
            .scenario(Topology::Pooled, Policy::LatencyAware, "poisson", 4, 200.0, 4.0)
            .is_some());
        assert!(result
            .scenario(Topology::Local, Policy::LatencyAware, "poisson", 4, 200.0, 4.0)
            .is_none());
        assert!(result
            .scenario(Topology::Local, Policy::LatencyAware, "poisson", 4, 200.0, 1.0)
            .is_some());
        assert!(result
            .scenario(Topology::Hybrid, Policy::LatencyAware, "poisson", 4, 200.0, 1.0)
            .is_none());
    }

    #[test]
    fn event_workload_identical_across_cells_of_one_arrival() {
        // Open-loop arrivals do not depend on service times, so every
        // (topology, policy, window) cell of a given arrival process
        // and rank count must see the same submitted request volume.
        let result = run_event_campaign(&quick_event_cfg());
        for key in ["synchronized", "poisson"] {
            let volumes: Vec<u64> = result
                .scenarios
                .iter()
                .filter(|s| s.arrival.key() == key && s.ranks == 4)
                .map(|s| s.summary.requests)
                .collect();
            assert!(!volumes.is_empty());
            assert!(
                volumes.iter().all(|&v| v == volumes[0]),
                "{key}: {volumes:?}"
            );
        }
    }

    #[test]
    fn event_json_is_deterministic_and_parses() {
        let cfg = quick_event_cfg();
        let a = crate::util::json::write(&run_event_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_event_campaign(&cfg).to_json());
        assert_eq!(a, b);
        let doc = crate::util::json::parse(&a).unwrap();
        let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
        for s in scenarios {
            for field in ["topology", "policy", "arrival", "ranks", "window_us", "summary"] {
                assert!(s.get(field).is_some(), "missing {field}");
            }
            let sum = s.get("summary").unwrap();
            for field in ["p50_us", "p99_us", "p999_us", "histogram", "slowdown_max"] {
                assert!(sum.get(field).is_some(), "missing summary.{field}");
            }
        }
    }

    #[test]
    fn event_tables_cover_the_sweep() {
        let cfg = quick_event_cfg();
        let result = run_event_campaign(&cfg);
        let tables = result.tables();
        assert_eq!(tables.len(), cfg.topologies.len());
        for (table, &topo) in tables.iter().zip(&cfg.topologies) {
            assert_eq!(
                table.x.len(),
                cfg.policies.len()
                    * cfg.arrivals.len()
                    * cfg.windows_us.len()
                    * oversubs_for(topo, &cfg.fabric_oversubs).len()
            );
            assert!(table.series("p999_us").is_some());
            assert!(table.series("contention_us").is_some());
        }
    }

    // ------------------------------------------------ cogsim mode

    fn quick_cog_cfg() -> CogCampaignConfig {
        CogCampaignConfig {
            policies: vec![Policy::RoundRobin, Policy::ModelAffinity],
            rank_counts: vec![4],
            fabric_oversubs: vec![1.0, 4.0],
            timesteps: 4,
            ..Default::default()
        }
    }

    #[test]
    fn cog_campaign_covers_every_cell() {
        let cfg = quick_cog_cfg();
        let result = run_cog_campaign(&cfg);
        let cells: usize = cfg
            .topologies
            .iter()
            .map(|&t| {
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.models_per_rank.len()
                    * cfg.swap_costs_s.len()
                    * cfg.overlaps.len()
                    * oversubs_for(t, &cfg.fabric_oversubs).len()
            })
            .sum();
        assert_eq!(result.scenarios.len(), cells);
        for s in &result.scenarios {
            assert!(s.summary.time_to_solution_s > 0.0, "{:?}/{:?}", s.topology, s.policy);
            assert_eq!(s.summary.timesteps as usize, cfg.timesteps);
            assert_eq!(
                s.summary.requests,
                (s.ranks * cfg.timesteps * cfg.requests_per_step) as u64
            );
            assert_eq!(s.summary.steps.len(), cfg.timesteps);
        }
        assert!(result
            .scenario(Topology::Pooled, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 4.0)
            .is_some());
        assert!(result
            .scenario(Topology::Local, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 4.0)
            .is_none());
        assert!(result
            .scenario(Topology::Hybrid, Policy::ModelAffinity, 4, 8, 2e-3, 0.0, 1.0)
            .is_none());
    }

    #[test]
    fn cog_json_is_deterministic_and_parses() {
        let cfg = quick_cog_cfg();
        let a = crate::util::json::write(&run_cog_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_cog_campaign(&cfg).to_json());
        assert_eq!(a, b);
        let doc = crate::util::json::parse(&a).unwrap();
        let scenarios = doc.get("scenarios").unwrap().as_array().unwrap();
        for s in scenarios {
            for field in ["topology", "policy", "ranks", "models", "swap_us", "overlap"] {
                assert!(s.get(field).is_some(), "missing {field}");
            }
            let sum = s.get("summary").unwrap();
            for field in [
                "time_to_solution_us",
                "total_compute_us",
                "total_queue_us",
                "total_swap_us",
                "total_network_us",
                "total_service_us",
                "straggler_counts",
                "steps",
            ] {
                assert!(sum.get(field).is_some(), "missing summary.{field}");
            }
            let steps = sum.get("steps").unwrap().as_array().unwrap();
            assert_eq!(steps.len(), cfg.timesteps);
        }
    }

    #[test]
    fn cog_tables_cover_the_sweep() {
        let cfg = quick_cog_cfg();
        let result = run_cog_campaign(&cfg);
        let tables = result.tables();
        assert_eq!(tables.len(), cfg.topologies.len());
        for (table, &topo) in tables.iter().zip(&cfg.topologies) {
            assert_eq!(
                table.x.len(),
                cfg.policies.len()
                    * cfg.rank_counts.len()
                    * cfg.models_per_rank.len()
                    * cfg.swap_costs_s.len()
                    * cfg.overlaps.len()
                    * oversubs_for(topo, &cfg.fabric_oversubs).len()
            );
            assert!(table.series("tts_ms").is_some());
            assert!(table.series("swap_ms").is_some());
            assert!(table.series("contention_ms").is_some());
        }
    }

    #[test]
    fn cog_local_topology_pays_no_network_on_the_critical_path() {
        let cfg = quick_cog_cfg();
        let s =
            run_cog_scenario(Topology::Local, Policy::LatencyAware, 4, 8, 0.0, 0.0, 1.0, &cfg);
        assert_eq!(s.summary.total_network_s, 0.0);
        assert_eq!(s.summary.total_contention_s, 0.0);
        let p =
            run_cog_scenario(Topology::Pooled, Policy::LatencyAware, 4, 8, 0.0, 0.0, 1.0, &cfg);
        assert!(p.summary.total_network_s > 0.0, "pool rides the link");
    }

    #[test]
    fn cog_fabric_oversubscription_never_speeds_the_pool_up() {
        // The knob's contract at the campaign level: pooled TTS is
        // monotone non-decreasing in oversubscription, and the
        // all-local topology is untouched by it.
        let cfg = quick_cog_cfg();
        let tts = |oversub: f64| {
            run_cog_scenario(Topology::Pooled, Policy::RoundRobin, 4, 8, 0.0, 0.0, oversub, &cfg)
                .summary
                .time_to_solution_s
        };
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let t = tts(oversub);
            assert!(t >= last - 1e-12, "oversub {oversub}: {t} < {last}");
            last = t;
        }
    }
}
