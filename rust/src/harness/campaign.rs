//! Scenario campaigns: sweep Hydra/MIR request streams across
//! cluster **topologies** × routing **policies** and emit a
//! deterministic JSON summary (p50/p95/p99 latency, samples/s,
//! backend utilisation) — the multi-accelerator extension of the
//! paper's single-device evaluation.
//!
//! Three topologies span the §VI design space:
//!
//! * **local**  — per-rank node-local GPUs (the paper's GPU
//!   convention: zero-cost link, Figs. 4–10);
//! * **pooled** — one shared disaggregated RDU pool across the
//!   Infiniband link (Figs. 15/16), heterogeneous tile groups
//!   (4-tile + 2-tile, the allocator's natural shapes);
//! * **hybrid** — the hot MIR model stays on per-rank local GPUs
//!   while the long-tail per-material Hermit instances share the
//!   remote pool ("local vs pooled vs hybrid" — the coupling-topology
//!   axis of AI-coupled HPC workflows).
//!
//! Everything runs in virtual time on the calibrated analytic models,
//! so a fixed seed yields a byte-stable summary
//! (`rust/tests/campaign_golden.rs` pins it).  MIR uses the paper's
//! no-layernorm variant (Fig. 20) so both architectures execute the
//! same network.

use crate::cluster::{Backend, BackendReport, Cluster, GpuBackend, Policy, RduBackend};
use crate::devices::{profiles, Api, Gpu, ModelProfile};
use crate::netsim::Link;
use crate::rdu::RduApi;
use crate::util::json::Value;
use crate::util::stats;
use crate::workload::{HydraWorkload, MirWorkload};

use std::collections::BTreeMap;

use super::table::Table;

/// The three coupling topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    Local,
    Pooled,
    Hybrid,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Local, Topology::Pooled, Topology::Hybrid];

    pub fn label(&self) -> &'static str {
        match self {
            Topology::Local => "per-rank local GPUs",
            Topology::Pooled => "shared disaggregated RDU pool",
            Topology::Hybrid => "hybrid (MIR local, Hermit pooled)",
        }
    }

    /// Stable snake_case key for JSON artifacts.
    pub fn key(&self) -> &'static str {
        match self {
            Topology::Local => "local",
            Topology::Pooled => "pooled",
            Topology::Hybrid => "hybrid",
        }
    }
}

/// Campaign knobs (defaults sized so the full 3×4 sweep runs in
/// milliseconds of wall time).
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// MPI ranks issuing requests.
    pub ranks: usize,
    /// Hydra zones per rank per timestep.
    pub zones_per_rank: usize,
    /// Per-material Hermit instances per rank.
    pub materials: usize,
    /// Simulated physics timesteps.
    pub timesteps: usize,
    /// Virtual seconds between timesteps (queues drain in between).
    pub step_period_s: f64,
    /// Base MIR mixed-zone count per rank per timestep.
    pub mir_base_zones: usize,
    /// Workload seed (fixed seed → byte-stable summary).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            ranks: 4,
            zones_per_rank: 200,
            materials: 8,
            timesteps: 12,
            step_period_s: 0.02,
            mir_base_zones: 1024,
            seed: 42,
        }
    }
}

/// Latency/throughput summary for one workload within a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSummary {
    pub requests: u64,
    pub samples: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_link_overhead_s: f64,
    /// Samples over the scenario makespan.
    pub samples_per_s: f64,
}

impl WorkloadSummary {
    fn from_run(latencies: &[f64], link_overheads: &[f64], samples: u64, makespan_s: f64) -> Self {
        WorkloadSummary {
            requests: latencies.len() as u64,
            samples,
            mean_s: stats::mean(latencies),
            p50_s: stats::percentile(latencies, 50.0),
            p95_s: stats::percentile(latencies, 95.0),
            p99_s: stats::percentile(latencies, 99.0),
            mean_link_overhead_s: stats::mean(link_overheads),
            samples_per_s: if makespan_s > 0.0 { samples as f64 / makespan_s } else { 0.0 },
        }
    }
}

/// One (topology, policy) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub topology: Topology,
    pub policy: Policy,
    pub hydra: WorkloadSummary,
    pub mir: WorkloadSummary,
    pub makespan_s: f64,
    pub backends: Vec<BackendReport>,
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config: CampaignConfig,
    pub scenarios: Vec<ScenarioResult>,
}

impl CampaignResult {
    /// Look up one cell.
    pub fn scenario(&self, topology: Topology, policy: Policy) -> &ScenarioResult {
        self.scenarios
            .iter()
            .find(|s| s.topology == topology && s.policy == policy)
            .expect("campaign ran every (topology, policy) cell")
    }

    /// Deterministic JSON document (BTreeMap key order; values
    /// rounded to fixed precision so the rendering is byte-stable).
    pub fn to_json(&self) -> Value {
        let mut root = BTreeMap::new();
        root.insert("config".to_string(), config_json(&self.config));
        root.insert(
            "scenarios".to_string(),
            Value::Array(self.scenarios.iter().map(scenario_json).collect()),
        );
        Value::Object(root)
    }

    /// One aligned table per topology (rows: policy; columns: key
    /// latency/throughput figures).
    pub fn tables(&self) -> Vec<Table> {
        Topology::ALL
            .iter()
            .map(|&topo| {
                let mut t = Table::new(
                    format!("Campaign — {} ({})", topo.key(), topo.label()),
                    "metric",
                );
                t.set_x([
                    "hydra_p50_us",
                    "hydra_p99_us",
                    "hydra_Msamples_per_s",
                    "mir_p50_us",
                    "mir_p99_us",
                ]);
                for policy in Policy::ALL {
                    let s = self.scenario(topo, policy);
                    t.add_series(
                        policy.key(),
                        vec![
                            s.hydra.p50_s * 1e6,
                            s.hydra.p99_s * 1e6,
                            s.hydra.samples_per_s / 1e6,
                            s.mir.p50_s * 1e6,
                            s.mir.p99_s * 1e6,
                        ],
                    );
                }
                t
            })
            .collect()
    }
}

/// Tiering: which backend indices serve which model class.
struct Tiering {
    hermit: Vec<usize>,
    mir: Vec<usize>,
}

/// Build a topology's backend fleet + tiering.
fn build_cluster(
    topology: Topology,
    ranks: usize,
    policy: Policy,
    pool_link: &Link,
) -> (Cluster, Tiering) {
    let local_gpu = |r: usize| -> Box<dyn Backend> {
        Box::new(GpuBackend::node_local(
            format!("gpu/rank{r}"),
            Gpu::a100(),
            Api::TrtCudaGraphs,
        ))
    };
    // The pool is deliberately heterogeneous — a full 4-tile group on
    // the optimised C++ stack next to a half-provisioned 2-tile group
    // still on the naive Python stack (the allocator's natural
    // shapes, Fig. 13's API spread): state-blind policies pay for not
    // seeing the difference.
    let pool = |start: usize| -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::with_link(
                format!("rdu/pool{start}"),
                4,
                RduApi::CppOptimized,
                pool_link.clone(),
            )),
            Box::new(RduBackend::with_link(
                format!("rdu/pool{}", start + 1),
                2,
                RduApi::Python,
                pool_link.clone(),
            )),
        ]
    };

    match topology {
        Topology::Local => {
            let backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let all: Vec<usize> = (0..backends.len()).collect();
            (Cluster::new(backends, policy), Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Pooled => {
            let backends = pool(0);
            let all: Vec<usize> = (0..backends.len()).collect();
            (Cluster::new(backends, policy), Tiering { hermit: all.clone(), mir: all })
        }
        Topology::Hybrid => {
            let mut backends: Vec<Box<dyn Backend>> = (0..ranks).map(local_gpu).collect();
            let gpu_idx: Vec<usize> = (0..backends.len()).collect();
            backends.extend(pool(0));
            let pool_idx: Vec<usize> = (gpu_idx.len()..backends.len()).collect();
            (Cluster::new(backends, policy), Tiering { hermit: pool_idx, mir: gpu_idx })
        }
    }
}

/// Campaign model mapping: Hermit requests use the Hermit profile;
/// MIR requests use the Fig-20 no-layernorm variant so GPU and RDU
/// backends execute the same network.
fn profile_for(model: &str) -> ModelProfile {
    if model.starts_with("mir") {
        profiles::mir_noln()
    } else {
        profiles::hermit()
    }
}

/// Run one (topology, policy) scenario.
pub fn run_scenario(topology: Topology, policy: Policy, cfg: &CampaignConfig) -> ScenarioResult {
    run_scenario_with_link(topology, policy, cfg, &Link::infiniband_cx6())
}

/// As [`run_scenario`], with an explicit pool link — the link
/// ablation behind the Fig-15/16 anchor test (swap the Infiniband
/// model for [`Link::local`] to measure the pure remote overhead).
pub fn run_scenario_with_link(
    topology: Topology,
    policy: Policy,
    cfg: &CampaignConfig,
    pool_link: &Link,
) -> ScenarioResult {
    let (mut cluster, tier) = build_cluster(topology, cfg.ranks, policy, pool_link);

    let hydra = HydraWorkload {
        ranks: cfg.ranks,
        zones_per_rank: cfg.zones_per_rank,
        materials: cfg.materials,
        inferences_per_zone: (2, 3),
        seed: cfg.seed,
    };
    let mir = MirWorkload {
        ranks: cfg.ranks,
        base_zones: cfg.mir_base_zones,
        variation: 0.4,
        seed: cfg.seed ^ 0x5EED,
    };
    let hermit_profile = profile_for("hermit");
    let mir_profile = profile_for("mir");

    let mut hydra_lat = Vec::new();
    let mut hydra_link = Vec::new();
    let mut hydra_samples = 0u64;
    let mut mir_lat = Vec::new();
    let mut mir_link = Vec::new();
    let mut mir_samples = 0u64;

    for t in 0..cfg.timesteps {
        cluster.advance_to(t as f64 * cfg.step_period_s);
        for req in hydra.timestep(t) {
            let routed =
                cluster.submit_among(&tier.hermit, &req.model, &hermit_profile, req.samples);
            hydra_lat.push(routed.latency_s);
            hydra_link.push(routed.link_overhead_s);
            hydra_samples += req.samples as u64;
        }
        for req in mir.timestep(t) {
            let routed = cluster.submit_among(&tier.mir, &req.model, &mir_profile, req.samples);
            mir_lat.push(routed.latency_s);
            mir_link.push(routed.link_overhead_s);
            mir_samples += req.samples as u64;
        }
    }

    let makespan_s = cluster.makespan_s();
    ScenarioResult {
        topology,
        policy,
        hydra: WorkloadSummary::from_run(&hydra_lat, &hydra_link, hydra_samples, makespan_s),
        mir: WorkloadSummary::from_run(&mir_lat, &mir_link, mir_samples, makespan_s),
        makespan_s,
        backends: cluster.report(),
    }
}

/// Run the full sweep: every topology under every routing policy.
pub fn run_campaign(cfg: &CampaignConfig) -> CampaignResult {
    let mut scenarios = Vec::new();
    for topology in Topology::ALL {
        for policy in Policy::ALL {
            scenarios.push(run_scenario(topology, policy, cfg));
        }
    }
    CampaignResult { config: cfg.clone(), scenarios }
}

// ------------------------------------------------------------- JSON

/// Microseconds at fixed 3-decimal precision (byte-stable rendering).
fn us(seconds: f64) -> Value {
    Value::Number((seconds * 1e9).round() / 1e3)
}

/// A plain number at fixed 3-decimal precision.
fn fixed3(v: f64) -> Value {
    Value::Number((v * 1e3).round() / 1e3)
}

fn count(v: u64) -> Value {
    Value::Number(v as f64)
}

fn config_json(cfg: &CampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(cfg.ranks as u64));
    m.insert("zones_per_rank".to_string(), count(cfg.zones_per_rank as u64));
    m.insert("materials".to_string(), count(cfg.materials as u64));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("step_period_us".to_string(), us(cfg.step_period_s));
    m.insert("mir_base_zones".to_string(), count(cfg.mir_base_zones as u64));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn workload_json(w: &WorkloadSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("requests".to_string(), count(w.requests));
    m.insert("samples".to_string(), count(w.samples));
    m.insert("mean_us".to_string(), us(w.mean_s));
    m.insert("p50_us".to_string(), us(w.p50_s));
    m.insert("p95_us".to_string(), us(w.p95_s));
    m.insert("p99_us".to_string(), us(w.p99_s));
    m.insert("mean_link_overhead_us".to_string(), us(w.mean_link_overhead_s));
    m.insert("samples_per_s".to_string(), fixed3(w.samples_per_s));
    Value::Object(m)
}

fn scenario_json(s: &ScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("hydra".to_string(), workload_json(&s.hydra));
    m.insert("mir".to_string(), workload_json(&s.mir));
    m.insert("makespan_us".to_string(), us(s.makespan_s));
    let makespan = s.makespan_s.max(f64::MIN_POSITIVE);
    m.insert(
        "backends".to_string(),
        Value::Array(
            s.backends
                .iter()
                .map(|b| {
                    let mut bm = BTreeMap::new();
                    bm.insert("name".to_string(), Value::String(b.name.clone()));
                    bm.insert("requests".to_string(), count(b.requests));
                    bm.insert("samples".to_string(), count(b.samples));
                    bm.insert("busy_us".to_string(), us(b.busy_s));
                    bm.insert(
                        "utilization".to_string(),
                        Value::Number((b.busy_s / makespan * 1e6).round() / 1e6),
                    );
                    Value::Object(bm)
                })
                .collect(),
        ),
    );
    Value::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> CampaignConfig {
        CampaignConfig { timesteps: 4, ..Default::default() }
    }

    #[test]
    fn campaign_covers_every_cell() {
        let result = run_campaign(&quick_cfg());
        assert_eq!(result.scenarios.len(), Topology::ALL.len() * Policy::ALL.len());
        for topo in Topology::ALL {
            for policy in Policy::ALL {
                let s = result.scenario(topo, policy);
                assert!(s.hydra.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.mir.requests > 0, "{topo:?}/{policy:?}");
                assert!(s.makespan_s > 0.0);
            }
        }
    }

    #[test]
    fn scenarios_conserve_samples() {
        // every scenario of a sweep sees the same workload; each must
        // route exactly the submitted sample volume
        let result = run_campaign(&quick_cfg());
        let expect_hydra = result.scenarios[0].hydra.samples;
        let expect_mir = result.scenarios[0].mir.samples;
        assert!(expect_hydra > 0 && expect_mir > 0);
        for s in &result.scenarios {
            assert_eq!(s.hydra.samples, expect_hydra, "{:?}/{:?}", s.topology, s.policy);
            assert_eq!(s.mir.samples, expect_mir);
            let routed: u64 = s.backends.iter().map(|b| b.samples).sum();
            assert_eq!(routed, expect_hydra + expect_mir);
        }
    }

    #[test]
    fn local_topology_has_zero_link_overhead() {
        let s = run_scenario(Topology::Local, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.hydra.mean_link_overhead_s, 0.0);
        assert_eq!(s.mir.mean_link_overhead_s, 0.0);
    }

    #[test]
    fn pooled_topology_pays_the_link() {
        let s = run_scenario(Topology::Pooled, Policy::LatencyAware, &quick_cfg());
        assert!(s.hydra.mean_link_overhead_s > 0.0);
        // MIR payloads (2×2304 els/sample) dwarf Hermit's 42+30
        assert!(s.mir.mean_link_overhead_s > s.hydra.mean_link_overhead_s);
    }

    #[test]
    fn hybrid_keeps_mir_local() {
        let s = run_scenario(Topology::Hybrid, Policy::LatencyAware, &quick_cfg());
        assert_eq!(s.mir.mean_link_overhead_s, 0.0, "hot model must stay local");
        assert!(s.hydra.mean_link_overhead_s > 0.0, "long tail rides the link");
        // GPU backends saw only MIR traffic, the pool only Hermit
        let gpu_requests: u64 = s
            .backends
            .iter()
            .filter(|b| b.name.starts_with("gpu/"))
            .map(|b| b.requests)
            .sum();
        assert_eq!(gpu_requests, s.mir.requests);
    }

    #[test]
    fn json_is_deterministic() {
        let cfg = quick_cfg();
        let a = crate::util::json::write(&run_campaign(&cfg).to_json());
        let b = crate::util::json::write(&run_campaign(&cfg).to_json());
        assert_eq!(a, b);
        // and parses back
        assert!(crate::util::json::parse(&a).is_ok());
        assert!(a.contains("\"topology\":\"hybrid\""), "{}", &a[..200.min(a.len())]);
    }
}
