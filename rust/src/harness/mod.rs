//! Experiment harnesses:
//!
//! * [`figures`]  — one regenerator per paper figure (4–20), each
//!   returning the figure's series as structured rows rendered as an
//!   aligned table + CSV (`repro repro <figN>` / `repro repro all`);
//! * [`scaling`]  — the ranks-per-DataScale feasibility frontier;
//! * [`scenario`] — the declarative scenario grid: **one** struct
//!   ([`scenario::Grid`]) describing every sweep axis × workload kind
//!   (topology, pool fleet composition, policy, ranks, arrival,
//!   batching window, models, swap cost, overlap, fabric
//!   oversubscription), plus the legacy per-mode config views;
//! * [`sweep`]    — the one sweep engine: expand a grid into cells,
//!   run each on its engine (analytic cluster / event sim / coupled
//!   cogsim), plus the legacy `run_campaign` / `run_event_campaign` /
//!   `run_cog_campaign` entry points as thin wrappers;
//! * [`report`]   — the one report layer: deterministic JSON
//!   documents (golden-pinned) and aligned tables for every result;
//! * [`table`]    — aligned-table + CSV rendering.
//!
//! (The former `harness::campaign` module was dissolved into
//! [`scenario`] / [`sweep`] / [`report`]; every public name it
//! exported is re-exported below.)

pub mod figures;
pub mod report;
pub mod scaling;
pub mod scenario;
pub mod sweep;
pub mod table;

pub use figures::{run_figure, FigureResult, FIGURES};
pub use scenario::{
    build_fabric_spec, build_fleet, Axes, CampaignConfig, CogCampaignConfig, ControlSpec,
    EventCampaignConfig, Fleet, Grid, Kind, Knobs, Scenario, Tiering, Topology,
};
pub use sweep::{
    run_campaign, run_cell, run_cell_ctl, run_cog_campaign, run_cog_scenario,
    run_control_campaign, run_event_campaign, run_event_scenario, run_grid, try_run_cell_ctl,
    try_run_cell_full, validate_cell_ctl,
    run_grid_threads, run_grid_threads_full, run_scenario, run_scenario_at,
    run_scenario_with_link,
    CampaignResult, CellResult, CellRun, CellSummary, CellTiming, CogCampaignResult,
    CogScenarioResult, ControlCampaignConfig, ControlCampaignResult, ControlCellResult,
    EventCampaignResult, EventScenarioResult, GridResult, GridRun, ScenarioResult,
    WorkloadSummary,
};
pub use table::Table;
