//! Experiment harnesses:
//!
//! * [`figures`]  — one regenerator per paper figure (4–20), each
//!   returning the figure's series as structured rows rendered as an
//!   aligned table + CSV (`repro repro <figN>` / `repro repro all`);
//! * [`scaling`]  — the ranks-per-DataScale feasibility frontier;
//! * [`campaign`] — multi-backend scenario campaigns: Hydra/MIR
//!   streams swept across cluster topologies (local / pooled /
//!   hybrid) × routing policies, emitting deterministic JSON
//!   (`repro campaign`), plus the event-sim mode sweeping rank count
//!   × arrival process × batching window (`repro eventsim`);
//! * [`table`]    — aligned-table + CSV rendering.

pub mod campaign;
pub mod figures;
pub mod scaling;
pub mod table;

pub use campaign::{
    run_campaign, run_event_campaign, CampaignConfig, CampaignResult, EventCampaignConfig,
    EventCampaignResult, Topology,
};
pub use figures::{run_figure, FigureResult, FIGURES};
pub use table::Table;
