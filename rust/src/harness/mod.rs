//! Figure regenerators: one function per figure in the paper's
//! evaluation (§V, Figs. 4–20), each returning the figure's series as
//! structured rows and rendering them as an aligned table + CSV.
//!
//! `repro <figN>` on the CLI calls into here; `repro all` regenerates
//! the complete evaluation into `results/`.

pub mod figures;
pub mod scaling;
pub mod table;

pub use figures::{run_figure, FigureResult, FIGURES};
pub use table::Table;
