//! Scaling analysis — the paper's §VI future-work question made
//! concrete: **how many MPI ranks can one disaggregated DataScale
//! node absorb** before (a) the tile allocation overloads, (b) the
//! Infiniband software path saturates, or (c) the in-the-loop latency
//! SLO breaks?
//!
//! Scenario (per the paper's stated rates, §IV-A): each rank runs
//! 10 000 zones with Hermit ⇒ 20–30 K inferences per timestep spread
//! over 8 material models; a physics timestep budget of `step_s`
//! seconds turns that into an offered load in samples/s.  Requests
//! ride the 100 Gb/s link at the operating mini-batch.

use std::collections::BTreeMap;

use crate::netsim::{payload_bytes, Link};
use crate::rdu::allocator::{allocate, Demand, NodeGeometry};
use crate::rdu::{RduApi, RduModel};

use super::table::Table;

/// Scenario knobs.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Inferences per rank per timestep (paper: 20–30 K at 10 K zones).
    pub inferences_per_rank_per_step: f64,
    /// Physics timestep wall budget, seconds.
    pub step_s: f64,
    /// Per-material request mini-batch at the accelerator.
    pub mini_batch: usize,
    /// Material models per rank.
    pub materials: usize,
    /// Remote in-the-loop latency SLO, seconds.
    pub latency_slo_s: f64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            inferences_per_rank_per_step: 25_000.0,
            step_s: 0.1,
            mini_batch: 64,
            materials: 8,
            latency_slo_s: 1e-3,
        }
    }
}

/// One row of the scaling table.
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub ranks: usize,
    pub offered_load: f64,
    pub worst_model_utilisation: f64,
    pub link_utilisation: f64,
    pub remote_latency_s: f64,
    pub slo_ok: bool,
}

/// Evaluate one rank count.
pub fn evaluate(scenario: &Scenario, ranks: usize) -> ScalingRow {
    let geometry = NodeGeometry::sn10_8();
    let api = RduApi::CppOptimized;
    let link = Link::infiniband_cx6();

    let per_rank_load = scenario.inferences_per_rank_per_step / scenario.step_s;
    let offered = per_rank_load * ranks as f64;
    let per_material = offered / scenario.materials as f64;

    // allocate the whole node for this demand set
    let demands: BTreeMap<String, Demand> = (0..scenario.materials)
        .map(|m| {
            (
                format!("hermit/mat{m}"),
                Demand {
                    profile: crate::devices::profiles::hermit(),
                    load: per_material,
                    mini_batch: scenario.mini_batch,
                },
            )
        })
        .collect();
    let alloc = allocate(geometry, &demands, api).expect("allocation");
    let worst = demands
        .iter()
        .map(|(m, d)| alloc.utilisation(m, d, api))
        .fold(0.0f64, f64::max);

    // link: every sample crosses twice (in + out) through the shared
    // software path
    let profile = crate::devices::profiles::hermit();
    let bytes_per_s =
        offered * payload_bytes(profile.input_elems, profile.output_elems, 1);
    let link_util = bytes_per_s / link.eff_bandwidth;

    // remote latency at the operating batch on the *largest* deployment
    // of the busiest model, queueing approximated by M/D/1 inflation
    let best_tiles = alloc
        .deployments
        .iter()
        .map(|d| d.tiles)
        .max()
        .unwrap_or(1);
    let rdu = RduModel::new(profile.clone(), best_tiles, api);
    let base = link.remote_latency_s(
        rdu.latency_best_s(scenario.mini_batch),
        payload_bytes(profile.input_elems, profile.output_elems, scenario.mini_batch),
    );
    // utilisation-dependent queueing inflation: 1/(1-rho) on the
    // dominant resource (capped for display)
    let rho = worst.max(link_util).min(0.999);
    let latency = base / (1.0 - rho);

    ScalingRow {
        ranks,
        offered_load: offered,
        worst_model_utilisation: worst,
        link_utilisation: link_util,
        remote_latency_s: latency,
        slo_ok: worst < 1.0 && link_util < 1.0 && latency <= scenario.latency_slo_s,
    }
}

/// Sweep rank counts; returns the table and the max SLO-feasible ranks.
pub fn sweep(scenario: &Scenario, rank_counts: &[usize]) -> (Table, Option<usize>) {
    let mut t = Table::new(
        format!(
            "Scaling: MPI ranks vs one SN10-8 node ({} inf/rank/step, {} ms step, SLO {} ms)",
            scenario.inferences_per_rank_per_step,
            scenario.step_s * 1e3,
            scenario.latency_slo_s * 1e3
        ),
        "ranks",
    );
    t.set_x(rank_counts.to_vec());
    let rows: Vec<ScalingRow> =
        rank_counts.iter().map(|&r| evaluate(scenario, r)).collect();
    t.add_series("offered_samples_per_s", rows.iter().map(|r| r.offered_load).collect());
    t.add_series(
        "worst_model_utilisation",
        rows.iter().map(|r| r.worst_model_utilisation).collect(),
    );
    t.add_series("link_utilisation", rows.iter().map(|r| r.link_utilisation).collect());
    t.add_series(
        "remote_latency_ms",
        rows.iter().map(|r| r.remote_latency_s * 1e3).collect(),
    );
    t.add_series(
        "slo_ok",
        rows.iter().map(|r| if r.slo_ok { 1.0 } else { 0.0 }).collect(),
    );
    let max_ok = rows.iter().filter(|r| r.slo_ok).map(|r| r.ranks).max();
    (t, max_ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_comfortable() {
        let row = evaluate(&Scenario::default(), 1);
        assert!(row.slo_ok, "{row:?}");
        assert!(row.worst_model_utilisation < 0.3);
        assert!(row.link_utilisation < 0.1);
    }

    #[test]
    fn saturation_eventually() {
        let s = Scenario::default();
        let row = evaluate(&s, 512);
        assert!(!row.slo_ok, "{row:?}");
    }

    #[test]
    fn monotone_in_ranks() {
        let s = Scenario::default();
        let mut prev_util = 0.0;
        for ranks in [1usize, 4, 16, 64] {
            let row = evaluate(&s, ranks);
            assert!(row.worst_model_utilisation >= prev_util);
            prev_util = row.worst_model_utilisation;
        }
    }

    #[test]
    fn sweep_reports_feasible_frontier() {
        let (table, max_ok) = sweep(&Scenario::default(), &[1, 2, 4, 8, 16, 32, 64, 128]);
        assert_eq!(table.x.len(), 8);
        let max_ok = max_ok.expect("at least one feasible point");
        assert!(max_ok >= 4, "a DataScale should absorb several ranks: {max_ok}");
        assert!(max_ok < 128, "must saturate within the sweep: {max_ok}");
    }
}
