//! The one report layer: deterministic JSON documents and aligned
//! tables for every sweep result.
//!
//! All three legacy campaign modes emit the same `{config, scenarios}`
//! root with BTreeMap key order and fixed-precision numbers, so a
//! fixed seed yields a byte-stable document — the committed goldens
//! (`rust/tests/golden/*.json`) pin the three legacy shapes, which is
//! why the per-mode leaf writers here are format definitions, not
//! duplicated logic: the sweep/emit skeleton around them exists once.
//!
//! [`GridResult`] (the unified `repro scenario` output) shares the
//! same scaffolding and reuses the per-kind summary writers, adding
//! the cell's full axis coordinates (kind, fleet, …) to each entry.

use std::collections::BTreeMap;

use crate::eventsim::{ArrivalProcess, CogSummary, EventSummary};
use crate::fluid::{FluidSummary, ScaleAnchor, ScaleCampaignConfig, ScaleCampaignResult, ScaleRow};
use crate::util::json::Value;

use super::scenario::{Grid, Topology};
use super::sweep::{
    AnalyticSummary, CampaignResult, CellSummary, CogCampaignResult, CogScenarioResult,
    ControlCampaignResult, ControlCellResult, EventCampaignResult, EventScenarioResult,
    GridResult, ScenarioResult, WorkloadSummary,
};
use super::table::Table;

// ------------------------------------------------ shared scaffolding

/// Microseconds at fixed 3-decimal precision (byte-stable rendering).
///
/// Non-finite inputs render as 0: `stats::percentile` returns NaN for
/// an empty population (e.g. the first-attempt latency set of a
/// fully-lossy control cell), and a golden field must never carry NaN
/// — 0 here is the explicit "no observations" rendering, matching the
/// pre-NaN behaviour byte-for-byte.
fn us(seconds: f64) -> Value {
    Value::Number(if seconds.is_finite() { (seconds * 1e9).round() / 1e3 } else { 0.0 })
}

/// A plain number at fixed 3-decimal precision (non-finite -> 0, same
/// contract as [`us`]).
fn fixed3(v: f64) -> Value {
    Value::Number(if v.is_finite() { (v * 1e3).round() / 1e3 } else { 0.0 })
}

fn count(v: u64) -> Value {
    Value::Number(v as f64)
}

/// JSON array of stable keys (topologies, policies, ...).
fn key_array<T>(items: &[T], key: impl Fn(&T) -> String) -> Value {
    Value::Array(items.iter().map(|i| Value::String(key(i))).collect())
}

/// JSON array of numbers at fixed precision.
fn num_array(items: &[f64]) -> Value {
    Value::Array(items.iter().map(|&v| fixed3(v)).collect())
}

/// The root campaign document every mode emits: `{config, scenarios}`.
fn doc_json(config: Value, scenarios: Vec<Value>) -> Value {
    let mut root = BTreeMap::new();
    root.insert("config".to_string(), config);
    root.insert("scenarios".to_string(), Value::Array(scenarios));
    Value::Object(root)
}

/// One aligned table per topology over a sweep's cells: `x_of` labels
/// each cell, `series` extracts the numeric columns.  (The analytic
/// mode keeps its bespoke metric-per-column layout; the event and
/// cog sweeps share this cell-per-row shape.)
fn topology_tables<S>(
    title_prefix: &str,
    topologies: &[Topology],
    scenarios: &[S],
    topo_of: impl Fn(&S) -> Topology,
    x_of: impl Fn(&S) -> String,
    series: &[(&str, &dyn Fn(&S) -> f64)],
) -> Vec<Table> {
    topologies
        .iter()
        .map(|&topo| {
            let cells: Vec<&S> =
                scenarios.iter().filter(|s| topo_of(s) == topo).collect();
            let mut t = Table::new(
                format!("{title_prefix} — {} ({})", topo.key(), topo.label()),
                "cell",
            );
            t.set_x(cells.iter().map(|s| x_of(s)));
            for (name, extract) in series {
                t.add_series(*name, cells.iter().map(|s| extract(s)).collect());
            }
            t
        })
        .collect()
}

// --------------------------------------------------- analytic leafs

fn config_json(cfg: &super::scenario::CampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(cfg.ranks as u64));
    m.insert("zones_per_rank".to_string(), count(cfg.zones_per_rank as u64));
    m.insert("materials".to_string(), count(cfg.materials as u64));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("step_period_us".to_string(), us(cfg.step_period_s));
    m.insert("mir_base_zones".to_string(), count(cfg.mir_base_zones as u64));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn workload_json(w: &WorkloadSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("requests".to_string(), count(w.requests));
    m.insert("samples".to_string(), count(w.samples));
    m.insert("mean_us".to_string(), us(w.mean_s));
    m.insert("p50_us".to_string(), us(w.p50_s));
    m.insert("p95_us".to_string(), us(w.p95_s));
    m.insert("p99_us".to_string(), us(w.p99_s));
    m.insert("mean_link_overhead_us".to_string(), us(w.mean_link_overhead_s));
    m.insert("samples_per_s".to_string(), fixed3(w.samples_per_s));
    Value::Object(m)
}

/// The analytic payload `{hydra, mir, makespan_us, backends}` —
/// shared by the legacy scenario entries and the unified grid cells.
fn analytic_summary_fields(
    m: &mut BTreeMap<String, Value>,
    hydra: &WorkloadSummary,
    mir: &WorkloadSummary,
    makespan_s: f64,
    backends: &[crate::cluster::BackendReport],
) {
    m.insert("hydra".to_string(), workload_json(hydra));
    m.insert("mir".to_string(), workload_json(mir));
    m.insert("makespan_us".to_string(), us(makespan_s));
    let makespan = makespan_s.max(f64::MIN_POSITIVE);
    m.insert(
        "backends".to_string(),
        Value::Array(
            backends
                .iter()
                .map(|b| {
                    let mut bm = BTreeMap::new();
                    bm.insert("name".to_string(), Value::String(b.name.clone()));
                    bm.insert("requests".to_string(), count(b.requests));
                    bm.insert("samples".to_string(), count(b.samples));
                    bm.insert("busy_us".to_string(), us(b.busy_s));
                    bm.insert(
                        "utilization".to_string(),
                        Value::Number((b.busy_s / makespan * 1e6).round() / 1e6),
                    );
                    Value::Object(bm)
                })
                .collect(),
        ),
    );
}

fn scenario_json(s: &ScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    analytic_summary_fields(&mut m, &s.hydra, &s.mir, s.makespan_s, &s.backends);
    Value::Object(m)
}

impl CampaignResult {
    /// Deterministic JSON document (BTreeMap key order; values
    /// rounded to fixed precision so the rendering is byte-stable).
    pub fn to_json(&self) -> Value {
        doc_json(
            config_json(&self.config),
            self.scenarios.iter().map(scenario_json).collect(),
        )
    }

    /// One aligned table per topology (rows: policy; columns: key
    /// latency/throughput figures).
    pub fn tables(&self) -> Vec<Table> {
        use crate::cluster::Policy;
        Topology::ALL
            .iter()
            .map(|&topo| {
                let mut t = Table::new(
                    format!("Campaign — {} ({})", topo.key(), topo.label()),
                    "metric",
                );
                t.set_x([
                    "hydra_p50_us",
                    "hydra_p99_us",
                    "hydra_Msamples_per_s",
                    "mir_p50_us",
                    "mir_p99_us",
                ]);
                for policy in Policy::ALL {
                    let s = self.scenario(topo, policy);
                    t.add_series(
                        policy.key(),
                        vec![
                            s.hydra.p50_s * 1e6,
                            s.hydra.p99_s * 1e6,
                            s.hydra.samples_per_s / 1e6,
                            s.mir.p50_s * 1e6,
                            s.mir.p99_s * 1e6,
                        ],
                    );
                }
                t
            })
            .collect()
    }
}

// ------------------------------------------------------- event leafs

fn arrival_json(a: &ArrivalProcess) -> Value {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Value::String(a.key().to_string()));
    match *a {
        ArrivalProcess::Synchronized { period_s, jitter_s } => {
            m.insert("period_us".to_string(), us(period_s));
            m.insert("jitter_us".to_string(), us(jitter_s));
        }
        ArrivalProcess::Poisson { rate_per_rank } => {
            m.insert("rate_per_rank".to_string(), fixed3(rate_per_rank));
        }
        ArrivalProcess::ClosedLoop { think_s } => {
            m.insert("think_us".to_string(), us(think_s));
        }
    }
    Value::Object(m)
}

fn event_config_json(cfg: &super::scenario::EventCampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topologies".to_string(), key_array(&cfg.topologies, |t| t.key().to_string()));
    m.insert("policies".to_string(), key_array(&cfg.policies, |p| p.key().to_string()));
    m.insert(
        "rank_counts".to_string(),
        Value::Array(cfg.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "arrivals".to_string(),
        Value::Array(cfg.arrivals.iter().map(arrival_json).collect()),
    );
    m.insert("windows_us".to_string(), num_array(&cfg.windows_us));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("max_batch".to_string(), count(cfg.max_batch as u64));
    m.insert("materials".to_string(), count(cfg.materials as u64));
    m.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(cfg.samples_per_request.0 as u64),
            count(cfg.samples_per_request.1 as u64),
        ]),
    );
    m.insert("requests_per_burst".to_string(), count(cfg.requests_per_burst as u64));
    m.insert("mir_every".to_string(), count(cfg.mir_every as u64));
    m.insert("mir_samples".to_string(), count(cfg.mir_samples as u64));
    m.insert("horizon_us".to_string(), us(cfg.horizon_s));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn event_summary_json(s: &EventSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("requests".to_string(), count(s.requests));
    m.insert("samples".to_string(), count(s.samples));
    m.insert("batches".to_string(), count(s.batches));
    m.insert("mean_batch_samples".to_string(), fixed3(s.mean_batch_samples));
    m.insert("mean_us".to_string(), us(s.latency.mean_s));
    m.insert("p50_us".to_string(), us(s.latency.p50_s));
    m.insert("p90_us".to_string(), us(s.latency.p90_s));
    m.insert("p99_us".to_string(), us(s.latency.p99_s));
    m.insert("p999_us".to_string(), us(s.latency.p999_s));
    m.insert("max_us".to_string(), us(s.latency.max_s));
    m.insert("mean_link_overhead_us".to_string(), us(s.mean_link_overhead_s));
    m.insert("mean_contention_us".to_string(), us(s.mean_contention_s));
    m.insert("samples_per_s".to_string(), fixed3(s.samples_per_s));
    m.insert("makespan_us".to_string(), us(s.makespan_s));
    m.insert("slowdown_max".to_string(), fixed3(s.slowdown_max));
    m.insert(
        "histogram".to_string(),
        Value::Array(
            s.latency
                .histogram
                .iter()
                .filter(|(_, c)| *c > 0)
                .map(|&(le_us, c)| {
                    let mut bm = BTreeMap::new();
                    bm.insert("le_us".to_string(), Value::Number(le_us));
                    bm.insert("count".to_string(), count(c));
                    Value::Object(bm)
                })
                .collect(),
        ),
    );
    m.insert("overflow".to_string(), count(s.latency.overflow));
    Value::Object(m)
}

fn event_scenario_json(s: &EventScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("arrival".to_string(), Value::String(s.arrival.key().to_string()));
    m.insert("ranks".to_string(), count(s.ranks as u64));
    m.insert("window_us".to_string(), fixed3(s.window_us));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    m.insert("summary".to_string(), event_summary_json(&s.summary));
    Value::Object(m)
}

impl EventCampaignResult {
    /// Deterministic JSON document (BTreeMap key order; fixed
    /// precision), golden-pinned by `rust/tests/campaign_golden.rs`.
    pub fn to_json(&self) -> Value {
        doc_json(
            event_config_json(&self.config),
            self.scenarios.iter().map(event_scenario_json).collect(),
        )
    }

    /// One aligned table per topology; one row per swept cell.
    pub fn tables(&self) -> Vec<Table> {
        topology_tables(
            "Event campaign",
            &self.config.topologies,
            &self.scenarios,
            |s: &EventScenarioResult| s.topology,
            |s| {
                format!(
                    "{}/{}/r{}/w{}/o{}",
                    s.policy.key(),
                    s.arrival.key(),
                    s.ranks,
                    s.window_us,
                    s.oversub
                )
            },
            &[
                ("p50_us", &|s: &EventScenarioResult| s.summary.latency.p50_s * 1e6),
                ("p99_us", &|s: &EventScenarioResult| s.summary.latency.p99_s * 1e6),
                ("p999_us", &|s: &EventScenarioResult| s.summary.latency.p999_s * 1e6),
                ("mean_batch", &|s: &EventScenarioResult| s.summary.mean_batch_samples),
                ("contention_us", &|s: &EventScenarioResult| {
                    s.summary.mean_contention_s * 1e6
                }),
                ("slowdown", &|s: &EventScenarioResult| s.summary.slowdown_max),
            ],
        )
    }
}

// --------------------------------------------------------- cog leafs

fn cog_config_json(cfg: &super::scenario::CogCampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topologies".to_string(), key_array(&cfg.topologies, |t| t.key().to_string()));
    m.insert("policies".to_string(), key_array(&cfg.policies, |p| p.key().to_string()));
    m.insert(
        "rank_counts".to_string(),
        Value::Array(cfg.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "models_per_rank".to_string(),
        Value::Array(cfg.models_per_rank.iter().map(|&m| count(m as u64)).collect()),
    );
    m.insert(
        "swap_costs_us".to_string(),
        Value::Array(cfg.swap_costs_s.iter().map(|&s| us(s)).collect()),
    );
    m.insert("overlaps".to_string(), num_array(&cfg.overlaps));
    m.insert("fabric_oversubs".to_string(), num_array(&cfg.fabric_oversubs));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("compute_us".to_string(), us(cfg.compute_s));
    m.insert("requests_per_step".to_string(), count(cfg.requests_per_step as u64));
    m.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(cfg.samples_per_request.0 as u64),
            count(cfg.samples_per_request.1 as u64),
        ]),
    );
    m.insert("mir_every".to_string(), count(cfg.mir_every as u64));
    m.insert("mir_samples".to_string(), count(cfg.mir_samples as u64));
    m.insert("residency_slots".to_string(), count(cfg.residency_slots as u64));
    m.insert("window_us".to_string(), fixed3(cfg.window_us));
    m.insert("max_batch".to_string(), count(cfg.max_batch as u64));
    m.insert("seed".to_string(), count(cfg.seed));
    Value::Object(m)
}

fn cog_summary_json(s: &CogSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(s.ranks));
    m.insert("timesteps".to_string(), count(s.timesteps));
    m.insert("requests".to_string(), count(s.requests));
    m.insert("samples".to_string(), count(s.samples));
    m.insert("batches".to_string(), count(s.batches));
    m.insert("time_to_solution_us".to_string(), us(s.time_to_solution_s));
    m.insert("mean_step_us".to_string(), us(s.mean_step_s));
    m.insert("total_compute_us".to_string(), us(s.total_compute_s));
    m.insert("total_queue_us".to_string(), us(s.total_queue_s));
    m.insert("total_swap_us".to_string(), us(s.total_swap_s));
    m.insert("total_network_us".to_string(), us(s.total_network_s));
    m.insert("total_contention_us".to_string(), us(s.total_contention_s));
    m.insert("total_service_us".to_string(), us(s.total_service_s));
    m.insert("swaps".to_string(), count(s.swaps));
    m.insert("swap_time_us".to_string(), us(s.swap_time_s));
    m.insert("max_spread_us".to_string(), us(s.max_spread_s));
    m.insert("request_p50_us".to_string(), us(s.latency.p50_s));
    m.insert("request_p99_us".to_string(), us(s.latency.p99_s));
    m.insert(
        "straggler_counts".to_string(),
        Value::Array(s.straggler_counts.iter().map(|&c| count(c)).collect()),
    );
    m.insert(
        "steps".to_string(),
        Value::Array(
            s.steps
                .iter()
                .map(|st| {
                    let mut sm = BTreeMap::new();
                    sm.insert("step".to_string(), count(st.step as u64));
                    sm.insert("duration_us".to_string(), us(st.duration_s()));
                    sm.insert("straggler".to_string(), count(st.straggler as u64));
                    sm.insert("compute_us".to_string(), us(st.compute_s));
                    sm.insert("queue_us".to_string(), us(st.queue_s));
                    sm.insert("swap_us".to_string(), us(st.swap_s));
                    sm.insert("network_us".to_string(), us(st.network_s));
                    sm.insert("contention_us".to_string(), us(st.contention_s));
                    sm.insert("service_us".to_string(), us(st.service_s));
                    sm.insert("spread_us".to_string(), us(st.spread_s));
                    Value::Object(sm)
                })
                .collect(),
        ),
    );
    Value::Object(m)
}

fn cog_scenario_json(s: &CogScenarioResult) -> Value {
    let mut m = BTreeMap::new();
    m.insert("topology".to_string(), Value::String(s.topology.key().to_string()));
    m.insert("policy".to_string(), Value::String(s.policy.key().to_string()));
    m.insert("ranks".to_string(), count(s.ranks as u64));
    m.insert("models".to_string(), count(s.models as u64));
    m.insert("swap_us".to_string(), us(s.swap_s));
    m.insert("overlap".to_string(), fixed3(s.overlap));
    m.insert("oversub".to_string(), fixed3(s.oversub));
    m.insert("summary".to_string(), cog_summary_json(&s.summary));
    Value::Object(m)
}

impl CogCampaignResult {
    /// Deterministic JSON document (BTreeMap key order; fixed
    /// precision), golden-pinned by `rust/tests/campaign_golden.rs`.
    pub fn to_json(&self) -> Value {
        doc_json(
            cog_config_json(&self.config),
            self.scenarios.iter().map(cog_scenario_json).collect(),
        )
    }

    /// One aligned table per topology; one row per swept cell.
    pub fn tables(&self) -> Vec<Table> {
        topology_tables(
            "CogSim campaign",
            &self.config.topologies,
            &self.scenarios,
            |s: &CogScenarioResult| s.topology,
            |s| {
                format!(
                    "{}/r{}/m{}/sw{}/ov{}/o{}",
                    s.policy.key(),
                    s.ranks,
                    s.models,
                    s.swap_s * 1e6,
                    s.overlap,
                    s.oversub
                )
            },
            &[
                ("tts_ms", &|s: &CogScenarioResult| s.summary.time_to_solution_s * 1e3),
                ("compute_ms", &|s: &CogScenarioResult| s.summary.total_compute_s * 1e3),
                ("queue_ms", &|s: &CogScenarioResult| s.summary.total_queue_s * 1e3),
                ("swap_ms", &|s: &CogScenarioResult| s.summary.total_swap_s * 1e3),
                ("network_ms", &|s: &CogScenarioResult| s.summary.total_network_s * 1e3),
                ("contention_ms", &|s: &CogScenarioResult| {
                    s.summary.total_contention_s * 1e3
                }),
                ("service_ms", &|s: &CogScenarioResult| s.summary.total_service_s * 1e3),
                ("swaps", &|s: &CogScenarioResult| s.summary.swaps as f64),
                ("spread_us", &|s: &CogScenarioResult| s.summary.max_spread_s * 1e6),
            ],
        )
    }
}

// ------------------------------------------------ control-plane leafs

fn control_cell_json(c: &ControlCellResult) -> Value {
    let s = &c.summary;
    let mut sm = BTreeMap::new();
    sm.insert("tts_us".to_string(), us(s.time_to_solution_s));
    sm.insert("requests".to_string(), count(s.requests));
    sm.insert("submitted".to_string(), count(s.submitted));
    sm.insert("retries".to_string(), count(s.retries));
    sm.insert("failed".to_string(), count(s.failed));
    sm.insert("rank_restarts".to_string(), count(s.rank_restarts));
    sm.insert("mean_active_backends".to_string(), fixed3(s.mean_active_backends));
    sm.insert("request_p50_us".to_string(), us(s.latency.p50_s));
    sm.insert("request_p99_us".to_string(), us(s.latency.p99_s));
    sm.insert("total_queue_us".to_string(), us(s.total_queue_s));
    sm.insert("total_network_us".to_string(), us(s.total_network_s));
    let mut m = BTreeMap::new();
    m.insert("label".to_string(), Value::String(c.label.clone()));
    m.insert("topology".to_string(), Value::String(c.topology.key().to_string()));
    m.insert("control".to_string(), Value::String(c.control.key.clone()));
    m.insert("summary".to_string(), Value::Object(sm));
    Value::Object(m)
}

/// The autoscaler must hold TTS within this factor of the
/// statically-provisioned optimum (the all-active pooled cell) —
/// pinned in the control golden and asserted by the chaos suite.
pub const AUTOSCALER_BOUND: f64 = 2.0;

impl ControlCampaignResult {
    /// Deterministic JSON document, golden-pinned by
    /// `rust/tests/golden/control_summary.json`: the per-cell compact
    /// summaries plus the headline — pooled absorbs a one-backend
    /// loss more gracefully than node-local, and the reactive
    /// autoscaler stays within [`AUTOSCALER_BOUND`] of the static
    /// optimum.
    pub fn to_json(&self) -> Value {
        let cfg = &self.config;
        let mut cm = BTreeMap::new();
        cm.insert("ranks".to_string(), count(cfg.ranks as u64));
        cm.insert("timesteps".to_string(), count(cfg.timesteps as u64));
        cm.insert("policy".to_string(), Value::String(cfg.policy.key().to_string()));
        cm.insert("oversub".to_string(), fixed3(cfg.oversub));
        cm.insert("seed".to_string(), count(cfg.seed));

        let loss_local = self.loss_ratio("local");
        let loss_pooled = self.loss_ratio("pooled");
        let auto_factor = self.autoscaler_factor();
        let mut hm = BTreeMap::new();
        hm.insert("loss_ratio_local".to_string(), fixed3(loss_local));
        hm.insert("loss_ratio_pooled".to_string(), fixed3(loss_pooled));
        hm.insert(
            "pooled_degrades_more_gracefully".to_string(),
            Value::Bool(loss_pooled < loss_local),
        );
        hm.insert("autoscaler_factor".to_string(), fixed3(auto_factor));
        hm.insert("autoscaler_bound".to_string(), fixed3(AUTOSCALER_BOUND));
        hm.insert(
            "autoscaler_within_bound".to_string(),
            Value::Bool(auto_factor <= AUTOSCALER_BOUND),
        );

        let mut root = BTreeMap::new();
        root.insert("config".to_string(), Value::Object(cm));
        root.insert(
            "cells".to_string(),
            Value::Array(self.cells.iter().map(control_cell_json).collect()),
        );
        root.insert("headline".to_string(), Value::Object(hm));
        Value::Object(root)
    }

    /// One aligned table: a row per control cell.
    pub fn tables(&self) -> Vec<Table> {
        let mut t = Table::new("Control-plane study".to_string(), "cell");
        t.set_x(self.cells.iter().map(|c| c.label.clone()));
        t.add_series(
            "tts_ms",
            self.cells.iter().map(|c| c.summary.time_to_solution_s * 1e3).collect(),
        );
        t.add_series(
            "retries",
            self.cells.iter().map(|c| c.summary.retries as f64).collect(),
        );
        t.add_series(
            "restarts",
            self.cells.iter().map(|c| c.summary.rank_restarts as f64).collect(),
        );
        t.add_series(
            "active",
            self.cells.iter().map(|c| c.summary.mean_active_backends).collect(),
        );
        t.add_series(
            "p99_us",
            self.cells.iter().map(|c| c.summary.latency.p99_s * 1e6).collect(),
        );
        vec![t]
    }
}

// ------------------------------------------------------ unified grid

fn grid_config_json(grid: &Grid) -> Value {
    let a = &grid.axes;
    let k = &grid.knobs;
    let mut m = BTreeMap::new();
    m.insert("kinds".to_string(), key_array(&a.kinds, |x| x.key().to_string()));
    m.insert("topologies".to_string(), key_array(&a.topologies, |t| t.key().to_string()));
    m.insert("fleets".to_string(), key_array(&a.fleets, |f| f.key()));
    m.insert("policies".to_string(), key_array(&a.policies, |p| p.key().to_string()));
    m.insert(
        "rank_counts".to_string(),
        Value::Array(a.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "arrivals".to_string(),
        Value::Array(a.arrivals.iter().map(arrival_json).collect()),
    );
    m.insert("windows_us".to_string(), num_array(&a.windows_us));
    m.insert(
        "models_per_rank".to_string(),
        Value::Array(a.models_per_rank.iter().map(|&x| count(x as u64)).collect()),
    );
    m.insert(
        "swap_costs_us".to_string(),
        Value::Array(a.swap_costs_s.iter().map(|&s| us(s)).collect()),
    );
    m.insert("overlaps".to_string(), num_array(&a.overlaps));
    m.insert("fabric_oversubs".to_string(), num_array(&a.fabric_oversubs));
    m.insert("controls".to_string(), key_array(&a.controls, |c| c.key.clone()));
    let mut kn = BTreeMap::new();
    kn.insert("materials".to_string(), count(k.materials as u64));
    kn.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(k.samples_per_request.0 as u64),
            count(k.samples_per_request.1 as u64),
        ]),
    );
    kn.insert("requests_per_burst".to_string(), count(k.requests_per_burst as u64));
    kn.insert("requests_per_step".to_string(), count(k.requests_per_step as u64));
    kn.insert("mir_every".to_string(), count(k.mir_every as u64));
    kn.insert("mir_samples".to_string(), count(k.mir_samples as u64));
    kn.insert("max_batch".to_string(), count(k.max_batch as u64));
    kn.insert("horizon_us".to_string(), us(k.horizon_s));
    kn.insert("timesteps".to_string(), count(k.timesteps as u64));
    kn.insert("compute_us".to_string(), us(k.compute_s));
    kn.insert("residency_slots".to_string(), count(k.residency_slots as u64));
    kn.insert("zones_per_rank".to_string(), count(k.zones_per_rank as u64));
    kn.insert("step_period_us".to_string(), us(k.step_period_s));
    kn.insert("mir_base_zones".to_string(), count(k.mir_base_zones as u64));
    kn.insert("seed".to_string(), count(k.seed));
    m.insert("knobs".to_string(), Value::Object(kn));
    Value::Object(m)
}

// ------------------------------------------------------ fluid leafs

fn fluid_summary_json(s: &FluidSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(s.ranks));
    m.insert("timesteps".to_string(), count(s.timesteps));
    m.insert("requests".to_string(), count(s.requests));
    m.insert("samples".to_string(), count(s.samples));
    m.insert("batches".to_string(), count(s.batches));
    m.insert("time_to_solution_us".to_string(), us(s.time_to_solution_s));
    m.insert("mean_step_us".to_string(), us(s.mean_step_s));
    m.insert("total_compute_us".to_string(), us(s.total_compute_s));
    m.insert("total_queue_us".to_string(), us(s.total_queue_s));
    m.insert("total_swap_us".to_string(), us(s.total_swap_s));
    m.insert("total_network_us".to_string(), us(s.total_network_s));
    m.insert("total_service_us".to_string(), us(s.total_service_s));
    m.insert("request_p50_us".to_string(), us(s.p50_s));
    m.insert("request_p99_us".to_string(), us(s.p99_s));
    m.insert("fixed_point_iterations".to_string(), count(s.fixed_point_iterations));
    m.insert("converged".to_string(), Value::Bool(s.converged));
    m.insert("bottleneck".to_string(), Value::String(s.bottleneck.clone()));
    Value::Object(m)
}

fn scale_config_json(cfg: &ScaleCampaignConfig) -> Value {
    let mut m = BTreeMap::new();
    m.insert(
        "rank_counts".to_string(),
        Value::Array(cfg.rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    m.insert(
        "pool_sizes".to_string(),
        Value::Array(cfg.pool_sizes.iter().map(|&p| count(p as u64)).collect()),
    );
    m.insert("policy".to_string(), Value::String(cfg.policy.key().to_string()));
    m.insert("oversub".to_string(), fixed3(cfg.oversub));
    m.insert("models_per_rank".to_string(), count(cfg.models_per_rank as u64));
    m.insert("swap_us".to_string(), us(cfg.swap_s));
    m.insert("overlap".to_string(), fixed3(cfg.overlap));
    m.insert("timesteps".to_string(), count(cfg.timesteps as u64));
    m.insert("compute_us".to_string(), us(cfg.compute_s));
    m.insert("requests_per_step".to_string(), count(cfg.requests_per_step as u64));
    m.insert(
        "samples_per_request".to_string(),
        Value::Array(vec![
            count(cfg.samples_per_request.0 as u64),
            count(cfg.samples_per_request.1 as u64),
        ]),
    );
    m.insert("residency_slots".to_string(), count(cfg.residency_slots as u64));
    m.insert("window_us".to_string(), fixed3(cfg.window_us));
    m.insert("max_batch".to_string(), count(cfg.max_batch as u64));
    m.insert(
        "anchor_rank_counts".to_string(),
        Value::Array(cfg.anchor_rank_counts.iter().map(|&r| count(r as u64)).collect()),
    );
    Value::Object(m)
}

fn scale_anchor_json(a: &ScaleAnchor) -> Value {
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(a.ranks as u64));
    m.insert("oversub".to_string(), fixed3(a.oversub));
    m.insert("swap_us".to_string(), us(a.swap_s));
    m.insert("event_tts_us".to_string(), us(a.event_tts_s));
    m.insert("fluid_tts_us".to_string(), us(a.fluid_tts_s));
    m.insert("tts_error".to_string(), fixed3(a.tts_error()));
    m.insert("within_bound".to_string(), Value::Bool(a.within_bound()));
    Value::Object(m)
}

fn scale_row_json(row: &ScaleRow) -> Value {
    let local_tts = row.local.time_to_solution_s;
    let mut m = BTreeMap::new();
    m.insert("ranks".to_string(), count(row.ranks as u64));
    m.insert("local".to_string(), fluid_summary_json(&row.local));
    m.insert(
        "pools".to_string(),
        Value::Array(
            row.pools
                .iter()
                .map(|(pool, s)| {
                    let mut p = BTreeMap::new();
                    p.insert("pool".to_string(), count(*pool as u64));
                    p.insert(
                        "speedup_vs_local".to_string(),
                        fixed3(local_tts / s.time_to_solution_s),
                    );
                    p.insert("summary".to_string(), fluid_summary_json(s));
                    Value::Object(p)
                })
                .collect(),
        ),
    );
    m.insert(
        "crossover_pool".to_string(),
        match row.crossover_pool {
            Some(p) => count(p as u64),
            None => Value::Null,
        },
    );
    Value::Object(m)
}

impl ScaleCampaignResult {
    /// Deterministic JSON document (`{config, rows}`), byte-identical
    /// to `python/sim/fluid.py`'s `scale_campaign_json` — the
    /// committed `scale_summary.json` golden pins both.
    pub fn to_json(&self) -> Value {
        let mut root = BTreeMap::new();
        root.insert("config".to_string(), scale_config_json(&self.config));
        root.insert(
            "rows".to_string(),
            Value::Array(self.rows.iter().map(scale_row_json).collect()),
        );
        root.insert(
            "anchors".to_string(),
            Value::Array(self.anchors.iter().map(scale_anchor_json).collect()),
        );
        Value::Object(root)
    }

    /// One aligned table per rank count: pooled TTS and speedup over
    /// the swept pool sizes, with the local baseline as the first
    /// column — plus, when the campaign ran with anchors, the
    /// event-engine cross-check table.
    pub fn tables(&self) -> Vec<Table> {
        let mut tables: Vec<Table> = self
            .rows
            .iter()
            .map(|row| {
                let mut t = Table::new(
                    format!(
                        "Scale[{} ranks] — crossover {}",
                        row.ranks,
                        row.crossover_pool
                            .map_or("none".to_string(), |p| format!("pool {p}")),
                    ),
                    "fleet",
                );
                t.set_x(
                    std::iter::once("local".to_string())
                        .chain(row.pools.iter().map(|(p, _)| format!("pool{p}"))),
                );
                t.add_series(
                    "tts_ms",
                    std::iter::once(row.local.time_to_solution_s * 1e3)
                        .chain(row.pools.iter().map(|(_, s)| s.time_to_solution_s * 1e3))
                        .collect(),
                );
                t.add_series(
                    "speedup",
                    std::iter::once(1.0)
                        .chain(row.pools.iter().map(|(_, s)| {
                            row.local.time_to_solution_s / s.time_to_solution_s
                        }))
                        .collect(),
                );
                t
            })
            .collect();
        if !self.anchors.is_empty() {
            let mut t = Table::new(
                "Scale anchors — event-engine cross-check (swap-free pooled cells)".to_string(),
                "ranks",
            );
            t.set_x(self.anchors.iter().map(|a| a.ranks.to_string()));
            t.add_series(
                "event_tts_ms",
                self.anchors.iter().map(|a| a.event_tts_s * 1e3).collect(),
            );
            t.add_series(
                "fluid_tts_ms",
                self.anchors.iter().map(|a| a.fluid_tts_s * 1e3).collect(),
            );
            t.add_series(
                "error_pct",
                self.anchors.iter().map(|a| a.tts_error() * 1e2).collect(),
            );
            tables.push(t);
        }
        tables
    }
}

impl GridResult {
    /// Deterministic JSON document: one output schema for every
    /// workload kind — each cell carries its full axis coordinates
    /// plus its kind's summary payload.
    pub fn to_json(&self) -> Value {
        let cells = self
            .cells
            .iter()
            .map(|c| {
                let sc = &c.scenario;
                let mut m = BTreeMap::new();
                m.insert("kind".to_string(), Value::String(sc.kind.key().to_string()));
                m.insert("topology".to_string(), Value::String(sc.topology.key().to_string()));
                m.insert("fleet".to_string(), Value::String(sc.fleet.key()));
                m.insert("policy".to_string(), Value::String(sc.policy.key().to_string()));
                m.insert("ranks".to_string(), count(sc.ranks as u64));
                m.insert("arrival".to_string(), Value::String(sc.arrival.key().to_string()));
                m.insert("window_us".to_string(), fixed3(sc.window_us));
                m.insert("models".to_string(), count(sc.models as u64));
                m.insert("swap_us".to_string(), us(sc.swap_s));
                m.insert("overlap".to_string(), fixed3(sc.overlap));
                m.insert("oversub".to_string(), fixed3(sc.oversub));
                m.insert(
                    "control".to_string(),
                    Value::String(self.grid.axes.control(sc.control).key),
                );
                let summary = match &c.summary {
                    CellSummary::Analytic(AnalyticSummary {
                        hydra,
                        mir,
                        makespan_s,
                        backends,
                    }) => {
                        let mut sm = BTreeMap::new();
                        analytic_summary_fields(&mut sm, hydra, mir, *makespan_s, backends);
                        Value::Object(sm)
                    }
                    CellSummary::Event(s) => event_summary_json(s),
                    CellSummary::Cog(s) => cog_summary_json(s),
                    CellSummary::Fluid(s) => fluid_summary_json(s),
                };
                m.insert("summary".to_string(), summary);
                Value::Object(m)
            })
            .collect();
        doc_json(grid_config_json(&self.grid), cells)
    }

    /// One aligned table per (kind, topology) over the grid's cells:
    /// a compact cross-kind view with one headline metric family per
    /// kind.
    pub fn tables(&self) -> Vec<Table> {
        let mut tables = Vec::new();
        for &kind in &self.grid.axes.kinds {
            let kind_cells: Vec<_> =
                self.cells.iter().filter(|c| c.scenario.kind == kind).collect();
            for &topo in &self.grid.axes.topologies {
                let rows: Vec<_> = kind_cells
                    .iter()
                    .filter(|c| c.scenario.topology == topo)
                    .collect();
                if rows.is_empty() {
                    continue;
                }
                let mut t = Table::new(
                    format!("Scenario[{}] — {} ({})", kind.key(), topo.key(), topo.label()),
                    "cell",
                );
                t.set_x(rows.iter().map(|c| {
                    let sc = &c.scenario;
                    format!(
                        "{}/{}/r{}/o{}",
                        sc.fleet.key(),
                        sc.policy.key(),
                        sc.ranks,
                        sc.oversub
                    )
                }));
                match kind {
                    super::scenario::Kind::Analytic => {
                        t.add_series(
                            "hydra_p99_us",
                            rows.iter()
                                .map(|c| {
                                    c.analytic().map_or(f64::NAN, |s| s.hydra.p99_s * 1e6)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "mir_p99_us",
                            rows.iter()
                                .map(|c| c.analytic().map_or(f64::NAN, |s| s.mir.p99_s * 1e6))
                                .collect(),
                        );
                        t.add_series(
                            "makespan_ms",
                            rows.iter()
                                .map(|c| {
                                    c.analytic().map_or(f64::NAN, |s| s.makespan_s * 1e3)
                                })
                                .collect(),
                        );
                    }
                    super::scenario::Kind::Event => {
                        t.add_series(
                            "p50_us",
                            rows.iter()
                                .map(|c| {
                                    c.event().map_or(f64::NAN, |s| s.latency.p50_s * 1e6)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "p99_us",
                            rows.iter()
                                .map(|c| {
                                    c.event().map_or(f64::NAN, |s| s.latency.p99_s * 1e6)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "contention_us",
                            rows.iter()
                                .map(|c| {
                                    c.event().map_or(f64::NAN, |s| s.mean_contention_s * 1e6)
                                })
                                .collect(),
                        );
                    }
                    super::scenario::Kind::Cog => {
                        t.add_series(
                            "tts_ms",
                            rows.iter()
                                .map(|c| {
                                    c.cog().map_or(f64::NAN, |s| s.time_to_solution_s * 1e3)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "network_ms",
                            rows.iter()
                                .map(|c| {
                                    c.cog().map_or(f64::NAN, |s| s.total_network_s * 1e3)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "swaps",
                            rows.iter()
                                .map(|c| c.cog().map_or(f64::NAN, |s| s.swaps as f64))
                                .collect(),
                        );
                    }
                    super::scenario::Kind::Fluid => {
                        t.add_series(
                            "tts_ms",
                            rows.iter()
                                .map(|c| {
                                    c.fluid().map_or(f64::NAN, |s| s.time_to_solution_s * 1e3)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "network_ms",
                            rows.iter()
                                .map(|c| {
                                    c.fluid().map_or(f64::NAN, |s| s.total_network_s * 1e3)
                                })
                                .collect(),
                        );
                        t.add_series(
                            "p99_us",
                            rows.iter()
                                .map(|c| c.fluid().map_or(f64::NAN, |s| s.p99_s * 1e6))
                                .collect(),
                        );
                    }
                }
                tables.push(t);
            }
        }
        tables
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eventsim::LatencyDist;
    use crate::util::json;

    #[test]
    fn writers_render_non_finite_as_zero() {
        // the empty-population quantile contract: stats returns NaN,
        // the writers must render it as the explicit 0 ("no
        // observations"), never as a NaN token in a golden
        assert_eq!(json::write(&us(f64::NAN)), "0");
        assert_eq!(json::write(&us(f64::INFINITY)), "0");
        assert_eq!(json::write(&us(f64::NEG_INFINITY)), "0");
        assert_eq!(json::write(&fixed3(f64::NAN)), "0");
        assert_eq!(json::write(&us(1.5e-6)), "1.5");
    }

    #[test]
    fn empty_latency_set_emits_no_nan() {
        // a fully-lossy control cell completes zero first-attempt
        // requests; its distribution quantiles are NaN and every
        // rendered field must still be finite
        let d = LatencyDist::from_latencies(&[]);
        assert!(d.p50_s.is_nan() && d.p99_s.is_nan());
        for v in [
            us(d.mean_s),
            us(d.p50_s),
            us(d.p90_s),
            us(d.p99_s),
            us(d.p999_s),
            us(d.max_s),
        ] {
            let text = json::write(&v);
            assert!(
                !text.contains("nan") && !text.contains("inf"),
                "non-finite leaked into a golden field: {text}"
            );
        }
    }
}
