//! Aligned-table + CSV rendering for figure series.

/// A simple column-oriented table: one label column (the x axis,
/// e.g. mini-batch size) and named numeric series.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub x_label: String,
    pub x: Vec<String>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Table {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>) -> Table {
        Table {
            title: title.into(),
            x_label: x_label.into(),
            x: Vec::new(),
            series: Vec::new(),
        }
    }

    pub fn set_x<T: ToString>(&mut self, xs: impl IntoIterator<Item = T>) {
        self.x = xs.into_iter().map(|x| x.to_string()).collect();
    }

    pub fn add_series(&mut self, name: impl Into<String>, ys: Vec<f64>) {
        let name = name.into();
        assert_eq!(
            ys.len(),
            self.x.len(),
            "series {name:?} length != x length"
        );
        self.series.push((name, ys));
    }

    /// Fetch a series by name (for shape tests).
    pub fn series(&self, name: &str) -> Option<&[f64]> {
        self.series
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, ys)| ys.as_slice())
    }

    /// Render as an aligned text table (the `repro` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        let mut widths = vec![self.x_label.len()];
        for (name, _) in &self.series {
            widths.push(name.len().max(12));
        }
        for (i, x) in self.x.iter().enumerate() {
            widths[0] = widths[0].max(x.len());
            let _ = i;
        }
        // header
        out.push_str(&format!("{:>w$}", self.x_label, w = widths[0]));
        for (j, (name, _)) in self.series.iter().enumerate() {
            out.push_str(&format!("  {:>w$}", name, w = widths[j + 1]));
        }
        out.push('\n');
        // rows
        for (i, x) in self.x.iter().enumerate() {
            out.push_str(&format!("{:>w$}", x, w = widths[0]));
            for (j, (_, ys)) in self.series.iter().enumerate() {
                out.push_str(&format!("  {:>w$}", format_sig(ys[i]), w = widths[j + 1]));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (the `results/` artifact).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.x_label.replace(',', ";"));
        for (name, _) in &self.series {
            out.push(',');
            out.push_str(&name.replace(',', ";"));
        }
        out.push('\n');
        for (i, x) in self.x.iter().enumerate() {
            out.push_str(x);
            for (_, ys) in &self.series {
                out.push_str(&format!(",{}", ys[i]));
            }
            out.push('\n');
        }
        out
    }
}

/// 4-significant-digit engineering formatting.
fn format_sig(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1e6 {
        format!("{:.3}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.3}K", v / 1e3)
    } else if a >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Fig X", "batch");
        t.set_x([1usize, 4, 16]);
        t.add_series("a100_ms", vec![0.65, 0.66, 0.67]);
        t.add_series("rdu_ms", vec![0.04, 0.045, 0.05]);
        t
    }

    #[test]
    fn render_contains_everything() {
        let s = sample().render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("a100_ms"));
        assert!(s.contains("0.65"));
        assert_eq!(s.lines().count(), 1 + 1 + 3);
    }

    #[test]
    fn csv_shape() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "batch,a100_ms,rdu_ms");
        assert_eq!(lines.len(), 4);
        assert!(lines[1].starts_with("1,0.65,"));
    }

    #[test]
    fn series_lookup() {
        let t = sample();
        assert_eq!(t.series("rdu_ms").unwrap()[0], 0.04);
        assert!(t.series("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "length")]
    fn mismatched_series_panics() {
        let mut t = Table::new("t", "x");
        t.set_x([1, 2]);
        t.add_series("bad", vec![1.0]);
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(format_sig(8_350_000.0), "8.350M");
        assert_eq!(format_sig(1534.0), "1.534K");
        assert_eq!(format_sig(0.00065), "0.00065");
        assert_eq!(format_sig(0.0), "0");
    }
}
