//! `repro` — the cogsim-disagg command line.
//!
//! ```text
//! repro serve  [--addr A] [--artifacts DIR] [--materials N] [--workers N]
//! repro client --addr A --model M [--batch B] [--requests N] [--pipeline D]
//! repro repro  <figN|all> [--out DIR]
//! repro trace  [--timesteps N] [--ranks N] [--zones N]
//! repro info   [--artifacts DIR]
//! ```
//!
//! Argument parsing is hand-rolled (no clap in the offline build).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use cogsim_disagg::coordinator::{Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::harness::{run_figure, FIGURES};
use cogsim_disagg::metrics::LatencyRecorder;
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::workload::HydraWorkload;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Flags that take no value: presence alone means `true`.
const BOOL_FLAGS: [&str; 1] = ["smoke"];

/// Tiny flag parser: positionals + `--key value` pairs, plus the
/// declared boolean switches (`repro cogsim --smoke`).  Value flags
/// still fail loudly when their value is missing.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    let value = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                    flags.insert(key.to_string(), value.clone());
                    i += 2;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "repro" => cmd_repro(&args),
        "scaling" => cmd_scaling(&args),
        "campaign" => cmd_campaign(&args),
        "eventsim" => cmd_eventsim(&args),
        "cogsim" => cmd_cogsim(&args),
        "fabric" => cmd_fabric(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        "repro — disaggregated CogSim inference (Wyatt et al., CS.DC 2021 reproduction)

USAGE:
  repro serve  [--addr 127.0.0.1:7471] [--artifacts artifacts] [--materials 8] [--workers 1]
  repro client --addr 127.0.0.1:7471 [--model hermit/mat0] [--batch 4]
               [--requests 100] [--pipeline 1]
  repro repro  <fig4..fig20|all> [--out results]
  repro scaling [--max-ranks 128] [--step-ms 100] [--slo-ms 1]
  repro campaign [--ranks 4] [--timesteps 12] [--zones 200] [--out results/campaign.json]
  repro eventsim [--horizon-ms 200] [--seed 42] [--out results/eventsim.json]
  repro cogsim [--ranks 4] [--timesteps 8] [--models 8] [--seed 42] [--smoke]
               [--out results/cogsim.json]
  repro fabric [--timesteps 8] [--seed 42] [--smoke] [--out results/fabric.json]
  repro trace  [--timesteps 3] [--ranks 4] [--zones 1000]
  repro info   [--artifacts artifacts]

The campaign modes sweep the pooled fabric's oversubscription
(1:1/2:1/4:1/8:1 by default in cogsim mode); `repro fabric` runs the
focused pooled-vs-node-local time-to-solution crossover sweep on the
contention-aware fabric simulator."
    );
}

/// Write a campaign JSON document, creating parent directories
/// (shared by every campaign subcommand).
fn write_json_out(out: &str, json: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, json).with_context(|| format!("writing {out}"))?;
    eprintln!("wrote {out}");
    Ok(())
}

/// Start the disaggregated inference server.
fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let addr = args.get("addr", "127.0.0.1:7471");
    let materials = args.get_usize("materials", 8)?;
    let workers = args.get_usize("workers", 1)?;

    let engine = if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("loading artifacts from {artifacts}/ ...");
        Engine::load(&artifacts, None)?
    } else {
        eprintln!(
            "no {artifacts}/manifest.json — serving the deterministic \
             simulated engine (run `make artifacts` for PJRT execution)"
        );
        Engine::sim_reference()
    };
    let mut registry = Registry::new();
    registry.register_materials("hermit", materials);
    registry.register("mir", "mir");
    registry.register("mir_noln", "mir_noln");

    let config = CoordinatorConfig {
        workers,
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::start(engine, registry, config)?);
    let server = Server::serve(Arc::clone(&coordinator), &addr)?;
    eprintln!(
        "serving {} instances on {} ({} workers)",
        coordinator.registry().len(),
        server.addr(),
        workers
    );
    eprintln!("instances: {:?}", coordinator.registry().instance_names());

    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive a server like one MPI rank.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7471");
    let model = args.get("model", "hermit/mat0");
    let batch = args.get_usize("batch", 4)?;
    let requests = args.get_usize("requests", 100)?;
    let pipeline = args.get_usize("pipeline", 1)?.max(1);

    let client = Client::connect(addr.as_str())?;
    let input_elems = if model.starts_with("mir") { 48 * 48 } else { 42 };
    let mut rng = Rng::new(7);
    let payload = rng.normal_vec(batch * input_elems);

    // warm-up (paper: 10 mini-batches)
    for _ in 0..10 {
        client.infer(&model, batch, &payload)?;
    }

    let mut latency = LatencyRecorder::new();
    let started = Instant::now();
    if pipeline == 1 {
        for _ in 0..requests {
            let t0 = Instant::now();
            client.infer(&model, batch, &payload)?;
            latency.record(t0.elapsed());
        }
    } else {
        // pipelined: keep `pipeline` requests in flight (paper §V-A)
        let mut inflight = std::collections::VecDeque::new();
        for _ in 0..requests {
            while inflight.len() >= pipeline {
                let (t0, rx): (Instant, _) = inflight.pop_front().unwrap();
                client.recv(rx)?;
                latency.record(t0.elapsed());
            }
            inflight.push_back((Instant::now(), client.submit(&model, batch, &payload)?));
        }
        for (t0, rx) in inflight {
            client.recv(rx)?;
            latency.record(t0.elapsed());
        }
    }
    let wall = started.elapsed().as_secs_f64();

    println!("model            {model}");
    println!("mini-batch       {batch}");
    println!("requests         {requests} (pipeline depth {pipeline})");
    println!("mean latency     {:.3} ms", latency.mean_s() * 1e3);
    println!(
        "p50/p95/p99      {:.3} / {:.3} / {:.3} ms",
        latency.p50_s() * 1e3,
        latency.p95_s() * 1e3,
        latency.p99_s() * 1e3
    );
    println!(
        "throughput       {:.0} samples/s",
        (requests * batch) as f64 / wall
    );
    Ok(())
}

/// Regenerate paper figures.
fn cmd_repro(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let out_dir = args.get("out", "results");
    std::fs::create_dir_all(&out_dir)?;

    let ids: Vec<&str> = if which == "all" {
        FIGURES.to_vec()
    } else {
        vec![which]
    };
    for id in ids {
        let fig = run_figure(id)?;
        println!("================ {} — {}", fig.id, fig.caption);
        for (i, table) in fig.tables.iter().enumerate() {
            println!("{}", table.render());
            let suffix = if fig.tables.len() > 1 {
                format!("{}_{}", fig.id, (b'a' + i as u8) as char)
            } else {
                fig.id.to_string()
            };
            let path = format!("{out_dir}/{suffix}.csv");
            std::fs::write(&path, table.to_csv())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

/// Scaling analysis: ranks-per-DataScale frontier (paper SVI).
fn cmd_scaling(args: &Args) -> Result<()> {
    let max_ranks = args.get_usize("max-ranks", 128)?;
    let step_ms = args.get_usize("step-ms", 100)?;
    let slo_ms = args.get_usize("slo-ms", 1)?;
    let scenario = cogsim_disagg::harness::scaling::Scenario {
        step_s: step_ms as f64 / 1e3,
        latency_slo_s: slo_ms as f64 / 1e3,
        ..Default::default()
    };
    let mut counts = Vec::new();
    let mut r = 1usize;
    while r <= max_ranks {
        counts.push(r);
        r *= 2;
    }
    let (table, max_ok) = cogsim_disagg::harness::scaling::sweep(&scenario, &counts);
    println!("{}", table.render());
    match max_ok {
        Some(n) => println!("max SLO-feasible ranks on one SN10-8 node: {n}"),
        None => println!("no feasible rank count under this SLO"),
    }
    Ok(())
}

/// Multi-backend scenario campaign: topologies × routing policies.
fn cmd_campaign(args: &Args) -> Result<()> {
    use cogsim_disagg::cluster::Policy;
    use cogsim_disagg::harness::campaign::{run_campaign, CampaignConfig, Topology};

    let cfg = CampaignConfig {
        ranks: args.get_usize("ranks", 4)?,
        zones_per_rank: args.get_usize("zones", 200)?,
        timesteps: args.get_usize("timesteps", 12)?,
        ..Default::default()
    };
    let out = args.get("out", "results/campaign.json");

    let result = run_campaign(&cfg);
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&out, &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline comparison: does state-aware routing beat blind
    // round-robin on tail latency in the hybrid topology?
    let la = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    let rr = result.scenario(Topology::Hybrid, Policy::RoundRobin);
    println!(
        "hybrid Hydra p99: latency-aware {:.1} us vs round-robin {:.1} us ({})",
        la.hydra.p99_s * 1e6,
        rr.hydra.p99_s * 1e6,
        if la.hydra.p99_s < rr.hydra.p99_s {
            "latency-aware wins"
        } else {
            "round-robin wins"
        }
    );
    Ok(())
}

/// Discrete-event campaign: rank count × arrival process × batching
/// window over the topology fleets.
fn cmd_eventsim(args: &Args) -> Result<()> {
    use cogsim_disagg::cluster::Policy;
    use cogsim_disagg::harness::campaign::{run_event_campaign, EventCampaignConfig, Topology};

    let mut cfg = EventCampaignConfig::default();
    let horizon_ms = args.get_usize("horizon-ms", 200)?;
    if horizon_ms == 0 {
        bail!("--horizon-ms must be positive");
    }
    cfg.horizon_s = horizon_ms as f64 / 1e3;
    cfg.seed = args.get_usize("seed", 42)? as u64;
    let out = args.get("out", "results/eventsim.json");

    let result = run_event_campaign(&cfg);
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&out, &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline: under bursty 64-rank arrivals on the pooled
    // topology, does the dynamic-batching window shrink tail latency?
    let ranks = *cfg.rank_counts.last().expect("rank sweep is non-empty");
    let windows = (cfg.windows_us.first().copied(), cfg.windows_us.last().copied());
    if let (Some(w_off), Some(w_on)) = windows {
        let off = result.scenario(
            Topology::Pooled,
            Policy::LatencyAware,
            "synchronized",
            ranks,
            w_off,
            1.0,
        );
        let on = result.scenario(
            Topology::Pooled,
            Policy::LatencyAware,
            "synchronized",
            ranks,
            w_on,
            1.0,
        );
        if let (Some(off), Some(on)) = (off, on) {
            println!(
                "pooled {ranks}-rank bursty p99: window {w_on} us {:.1} us vs window {w_off} us \
                 {:.1} us ({})",
                on.summary.latency.p99_s * 1e6,
                off.summary.latency.p99_s * 1e6,
                if on.summary.latency.p99_s < off.summary.latency.p99_s {
                    "batching wins the tail"
                } else {
                    "batching does not win here"
                }
            );
        }
    }
    Ok(())
}

/// Coupled CogSim campaign: time-to-solution across topology ×
/// policy × ranks × models × swap cost × overlap.
fn cmd_cogsim(args: &Args) -> Result<()> {
    use cogsim_disagg::cluster::Policy;
    use cogsim_disagg::harness::campaign::{run_cog_campaign, CogCampaignConfig, Topology};

    let mut cfg = CogCampaignConfig::default();
    cfg.rank_counts = vec![args.get_usize("ranks", 4)?];
    cfg.models_per_rank = vec![args.get_usize("models", 8)?];
    cfg.timesteps = args.get_usize("timesteps", cfg.timesteps)?;
    cfg.seed = args.get_usize("seed", 42)? as u64;
    if args.get_bool("smoke") {
        // CI-sized: one topology, two policies, three steps.
        cfg.topologies = vec![Topology::Pooled];
        cfg.policies = vec![Policy::RoundRobin, Policy::ModelAffinity];
        cfg.timesteps = cfg.timesteps.min(3);
        cfg.overlaps = vec![0.0];
        cfg.fabric_oversubs = vec![1.0, 8.0];
    }
    if cfg.timesteps == 0 {
        bail!("--timesteps must be positive");
    }
    let out = args.get("out", "results/cogsim.json");

    let result = run_cog_campaign(&cfg);
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&out, &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline: once swapping weights costs more than serving a
    // request, sticky model-affinity routing must beat blind
    // round-robin on time-to-solution (shared pool, serial coupling).
    let ranks = cfg.rank_counts[0];
    let models = cfg.models_per_rank[0];
    let swap = *cfg.swap_costs_s.last().expect("swap sweep is non-empty");
    let aff =
        result.scenario(Topology::Pooled, Policy::ModelAffinity, ranks, models, swap, 0.0, 1.0);
    let rr =
        result.scenario(Topology::Pooled, Policy::RoundRobin, ranks, models, swap, 0.0, 1.0);
    if let (Some(aff), Some(rr)) = (aff, rr) {
        println!(
            "pooled TTS at swap {:.0} us: model-affinity {:.2} ms vs round-robin {:.2} ms ({})",
            swap * 1e6,
            aff.summary.time_to_solution_s * 1e3,
            rr.summary.time_to_solution_s * 1e3,
            if aff.summary.time_to_solution_s < rr.summary.time_to_solution_s {
                "affinity wins"
            } else {
                "affinity does not win here"
            }
        );
    }
    Ok(())
}

/// Contention crossover on the flow-level fabric: pooled vs
/// node-local time-to-solution across rank count × oversubscription.
fn cmd_fabric(args: &Args) -> Result<()> {
    use cogsim_disagg::cluster::Policy;
    use cogsim_disagg::harness::campaign::{run_cog_campaign, CogCampaignConfig, Topology};

    let smoke = args.get_bool("smoke");
    let mut cfg = CogCampaignConfig {
        topologies: vec![Topology::Local, Topology::Pooled],
        policies: vec![Policy::LatencyAware],
        rank_counts: if smoke { vec![4, 32] } else { vec![4, 8, 16, 32] },
        models_per_rank: vec![8],
        swap_costs_s: vec![0.0],
        overlaps: vec![0.0],
        fabric_oversubs: if smoke { vec![1.0, 8.0] } else { vec![1.0, 2.0, 4.0, 8.0] },
        ..Default::default()
    };
    cfg.timesteps = args.get_usize("timesteps", cfg.timesteps)?;
    if smoke {
        cfg.timesteps = cfg.timesteps.min(3);
    }
    cfg.seed = args.get_usize("seed", 42)? as u64;
    if cfg.timesteps == 0 {
        bail!("--timesteps must be positive");
    }
    let out = args.get("out", "results/fabric.json");

    let result = run_cog_campaign(&cfg);
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&out, &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline: at what (rank count, oversubscription) does the
    // shared pool lose to per-rank local GPUs on time-to-solution?
    let policy = cfg.policies[0];
    let mut crossover: Option<(usize, f64)> = None;
    println!("pooled-vs-local TTS (ms), policy {}:", policy.key());
    for &ranks in &cfg.rank_counts {
        let local = result
            .scenario(Topology::Local, policy, ranks, 8, 0.0, 0.0, 1.0)
            .expect("local cell ran");
        let local_ms = local.summary.time_to_solution_s * 1e3;
        let mut row = format!("  ranks {ranks:>3}: local {local_ms:>8.2}  pooled");
        for &oversub in &cfg.fabric_oversubs {
            let pooled = result
                .scenario(Topology::Pooled, policy, ranks, 8, 0.0, 0.0, oversub)
                .expect("pooled cell ran");
            let pooled_ms = pooled.summary.time_to_solution_s * 1e3;
            let behind = pooled.summary.time_to_solution_s > local.summary.time_to_solution_s;
            row.push_str(&format!(
                " {oversub}:1={pooled_ms:.2}{}",
                if behind { "*" } else { "" }
            ));
            if behind && crossover.is_none() {
                crossover = Some((ranks, oversub));
            }
        }
        println!("{row}");
    }
    match crossover {
        Some((ranks, oversub)) => println!(
            "pooled falls behind node-local from {ranks} ranks at {oversub}:1 \
             oversubscription (* = pooled slower)"
        ),
        None => println!("pooled never falls behind node-local in this sweep"),
    }
    Ok(())
}

/// Print a Hydra-like request trace (workload inspection).
fn cmd_trace(args: &Args) -> Result<()> {
    let timesteps = args.get_usize("timesteps", 3)?;
    let ranks = args.get_usize("ranks", 4)?;
    let zones = args.get_usize("zones", 1000)?;
    let w = HydraWorkload { ranks, zones_per_rank: zones, ..Default::default() };
    println!(
        "hydra workload: {ranks} ranks x {zones} zones, {} materials, ~{} inferences/timestep",
        w.materials,
        w.expected_inferences_per_timestep()
    );
    for t in 0..timesteps {
        let reqs = w.timestep(t);
        let total: usize = reqs.iter().map(|r| r.samples).sum();
        println!("timestep {t}: {} requests, {total} samples", reqs.len());
        for r in reqs.iter().take(6) {
            println!("  rank {} -> {:<14} {} samples", r.rank, r.model, r.samples);
        }
        if reqs.len() > 6 {
            println!("  ... {} more", reqs.len() - 6);
        }
    }
    Ok(())
}

/// Show manifest/runtime info.
fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let manifest = cogsim_disagg::runtime::Manifest::load(&artifacts)?;
    println!("artifacts: {}", manifest.dir.display());
    println!("dtype {}  seed {}", manifest.dtype, manifest.seed);
    for (name, spec) in &manifest.models {
        println!(
            "  {name:<10} params {:>9}  in {:?} out {:?}  batches {:?}",
            spec.param_count,
            spec.input_shape,
            spec.output_shape,
            spec.batch_ladder()
        );
    }
    Ok(())
}
