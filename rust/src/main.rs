//! `repro` — the cogsim-disagg command line.
//!
//! Argument parsing is hand-rolled (no clap in the offline build),
//! but declarative: every flag lives in the single [`FLAGS`] table
//! (name, type, default, help, commands it applies to), the usage
//! text is derived from it, and unknown flags fail loudly with the
//! command's valid set.  `repro scenario` runs the declarative
//! scenario grid; `campaign`, `eventsim`, `cogsim` and `fabric` are
//! thin aliases that pre-shape the same grid.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::coordinator::{Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::eventsim::ArrivalProcess;
use cogsim_disagg::fluid::{run_scale_campaign_with_anchors, ScaleCampaignConfig};
use cogsim_disagg::harness::{
    run_control_campaign, run_figure, run_grid_threads_full, try_run_cell_full, Axes,
    CampaignConfig, CellTiming, CogCampaignConfig, ControlCampaignConfig, ControlSpec,
    EventCampaignConfig, Fleet, Grid, GridResult, Kind, Knobs, Scenario, Topology, FIGURES,
};
use cogsim_disagg::metrics::LatencyRecorder;
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::json::{self, Value};
use cogsim_disagg::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

// -------------------------------------------------------- flag table

/// How a flag's value is parsed (and rendered in the usage text).
#[derive(Debug, Clone, Copy, PartialEq)]
enum FlagKind {
    /// `--flag N`
    Usize,
    /// `--flag STR`
    Str,
    /// `--flag A,B,...` (comma-separated list)
    List,
    /// Presence alone means `true`.
    Bool,
}

/// One declarative flag: the single source of truth for parsing,
/// defaults, and the derived usage text.  A name may appear in
/// several rows with disjoint command sets (per-command defaults).
struct FlagSpec {
    name: &'static str,
    kind: FlagKind,
    default: &'static str,
    help: &'static str,
    cmds: &'static [&'static str],
}

/// Every flag of every subcommand.  `repro help` renders this table;
/// the parser rejects flags not declared for the running command.
const FLAGS: &[FlagSpec] = &[
    // serving
    FlagSpec { name: "addr", kind: FlagKind::Str, default: "127.0.0.1:7471",
               help: "server address", cmds: &["serve", "client"] },
    FlagSpec { name: "artifacts", kind: FlagKind::Str, default: "artifacts",
               help: "AOT artifact directory", cmds: &["serve", "info"] },
    FlagSpec { name: "materials", kind: FlagKind::Usize, default: "8",
               help: "per-material Hermit instances", cmds: &["serve"] },
    FlagSpec { name: "workers", kind: FlagKind::Usize, default: "1",
               help: "coordinator worker threads", cmds: &["serve"] },
    FlagSpec { name: "model", kind: FlagKind::Str, default: "hermit/mat0",
               help: "target model instance", cmds: &["client"] },
    FlagSpec { name: "batch", kind: FlagKind::Usize, default: "4",
               help: "samples per request", cmds: &["client"] },
    FlagSpec { name: "requests", kind: FlagKind::Usize, default: "100",
               help: "requests to send", cmds: &["client"] },
    FlagSpec { name: "pipeline", kind: FlagKind::Usize, default: "1",
               help: "requests kept in flight", cmds: &["client"] },
    // figures + scaling
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results",
               help: "output directory for figure CSVs", cmds: &["repro"] },
    FlagSpec { name: "max-ranks", kind: FlagKind::Usize, default: "128",
               help: "largest rank count to probe", cmds: &["scaling"] },
    FlagSpec { name: "step-ms", kind: FlagKind::Usize, default: "100",
               help: "timestep period, ms", cmds: &["scaling"] },
    FlagSpec { name: "slo-ms", kind: FlagKind::Usize, default: "1",
               help: "per-request latency SLO, ms", cmds: &["scaling"] },
    // grid aliases (legacy per-mode knobs)
    FlagSpec { name: "ranks", kind: FlagKind::Usize, default: "4",
               help: "MPI ranks", cmds: &["campaign", "cogsim"] },
    FlagSpec { name: "zones", kind: FlagKind::Usize, default: "200",
               help: "Hydra zones per rank per timestep", cmds: &["campaign"] },
    FlagSpec { name: "timesteps", kind: FlagKind::Usize, default: "12",
               help: "simulated timesteps", cmds: &["campaign"] },
    FlagSpec { name: "timesteps", kind: FlagKind::Usize, default: "8",
               help: "bulk-synchronous timesteps", cmds: &["cogsim", "fabric", "scenario", "control"] },
    FlagSpec { name: "horizon-ms", kind: FlagKind::Usize, default: "200",
               help: "arrival horizon, ms", cmds: &["eventsim", "scenario"] },
    FlagSpec { name: "seed", kind: FlagKind::Usize, default: "42",
               help: "workload seed (fixed seed = byte-stable JSON)",
               cmds: &["eventsim", "cogsim", "fabric", "scenario", "control"] },
    FlagSpec { name: "models", kind: FlagKind::Usize, default: "8",
               help: "target models per rank", cmds: &["cogsim"] },
    FlagSpec { name: "smoke", kind: FlagKind::Bool, default: "",
               help: "CI-sized sweep", cmds: &["cogsim", "fabric", "scenario"] },
    FlagSpec { name: "threads", kind: FlagKind::Usize, default: "0",
               help: "sweep worker threads (0 = all cores, 1 = sequential)",
               cmds: &["scenario", "campaign", "eventsim", "cogsim", "fabric"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/campaign.json",
               help: "JSON output path", cmds: &["campaign"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/eventsim.json",
               help: "JSON output path", cmds: &["eventsim"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/cogsim.json",
               help: "JSON output path", cmds: &["cogsim"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/fabric.json",
               help: "JSON output path", cmds: &["fabric"] },
    // the unified scenario grid
    FlagSpec { name: "kinds", kind: FlagKind::List, default: "cog",
               help: "workload kinds: analytic|event|cog|fluid", cmds: &["scenario"] },
    FlagSpec { name: "topologies", kind: FlagKind::List, default: "local,pooled",
               help: "coupling topologies: local|pooled|hybrid", cmds: &["scenario"] },
    FlagSpec { name: "fleets", kind: FlagKind::List, default: "default",
               help: "pool compositions: default or <G>g<R>r (e.g. 4g2r)",
               cmds: &["scenario"] },
    FlagSpec { name: "policies", kind: FlagKind::List, default: "round_robin,latency_aware",
               help: "routing policies", cmds: &["scenario"] },
    FlagSpec { name: "ranks", kind: FlagKind::List, default: "4,32",
               help: "MPI rank counts", cmds: &["scenario"] },
    FlagSpec { name: "arrivals", kind: FlagKind::List, default: "synchronized",
               help: "arrival processes (event kind): synchronized|poisson|closed_loop",
               cmds: &["scenario"] },
    FlagSpec { name: "windows-us", kind: FlagKind::List, default: "0",
               help: "batching windows in us, 0 = off", cmds: &["scenario"] },
    FlagSpec { name: "models", kind: FlagKind::List, default: "8",
               help: "models per rank (cog kind)", cmds: &["scenario"] },
    FlagSpec { name: "swaps-us", kind: FlagKind::List, default: "0",
               help: "residency swap costs in us (cog kind)", cmds: &["scenario"] },
    FlagSpec { name: "overlaps", kind: FlagKind::List, default: "0",
               help: "compute/inference overlap fractions (cog kind)", cmds: &["scenario"] },
    FlagSpec { name: "oversubs", kind: FlagKind::List, default: "1,4",
               help: "fabric oversubscription factors", cmds: &["scenario"] },
    FlagSpec { name: "controls", kind: FlagKind::List, default: "static",
               help: "control-plane traces (event/cog kinds): static or \
                      `+`-joined leave:IDX@T|join:IDX@T|degrade:F@T|restore@T|\
                      rankfail:R@T|auto:INIT:MIN-MAX:LO:HI (times/thresholds in us)",
               cmds: &["scenario"] },
    FlagSpec { name: "list", kind: FlagKind::Bool, default: "",
               help: "print the grid's axes and defaults, then exit", cmds: &["scenario"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/scenario.json",
               help: "JSON output path", cmds: &["scenario"] },
    // the control-plane resilience study
    FlagSpec { name: "ranks", kind: FlagKind::Usize, default: "4",
               help: "MPI ranks (= devices per fleet)", cmds: &["control"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/control.json",
               help: "JSON output path", cmds: &["control"] },
    // the fluid-tier scale-out study
    FlagSpec { name: "smoke", kind: FlagKind::Bool, default: "",
               help: "CI-sized sweep (2 rank counts x 2 pool sizes)", cmds: &["scale"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/scale.json",
               help: "JSON output path", cmds: &["scale"] },
    // the flight recorder
    FlagSpec { name: "timesteps", kind: FlagKind::Usize, default: "8",
               help: "bulk-synchronous timesteps", cmds: &["trace"] },
    FlagSpec { name: "ranks", kind: FlagKind::Usize, default: "32",
               help: "MPI ranks", cmds: &["trace"] },
    FlagSpec { name: "swap-us", kind: FlagKind::Usize, default: "200",
               help: "residency swap cost, us", cmds: &["trace"] },
    FlagSpec { name: "seed", kind: FlagKind::Usize, default: "42",
               help: "workload seed (fixed seed = byte-stable trace)", cmds: &["trace"] },
    FlagSpec { name: "smoke", kind: FlagKind::Bool, default: "",
               help: "CI-sized cell", cmds: &["trace"] },
    FlagSpec { name: "out", kind: FlagKind::Str, default: "results/trace.json",
               help: "attribution JSON path (timeline goes to <stem>.trace.json)",
               cmds: &["trace"] },
    // flight-recorder side-channels on the grid commands
    FlagSpec { name: "trace", kind: FlagKind::Str, default: "",
               help: "arm the flight recorder and write a merged Perfetto timeline to PATH",
               cmds: &["scenario", "campaign", "eventsim", "cogsim", "fabric"] },
    FlagSpec { name: "timings", kind: FlagKind::Str, default: "",
               help: "write per-cell wall-clock timings JSON to PATH (kept out of the \
                      deterministic summary)",
               cmds: &["scenario", "campaign", "eventsim", "cogsim", "fabric"] },
];

/// `(command, positional synopsis, one-line description)` — the
/// usage text's skeleton; flag lines are derived from [`FLAGS`].
const COMMANDS: &[(&str, &str, &str)] = &[
    ("serve", "", "start the disaggregated inference server"),
    ("client", "", "drive a server like one MPI rank"),
    ("repro", "<fig4..fig20|all>", "regenerate paper figures"),
    ("scaling", "", "ranks-per-DataScale feasibility frontier"),
    ("scenario", "", "run the declarative scenario grid (axes x workload kind)"),
    ("campaign", "", "alias: analytic grid (topology x policy)"),
    ("eventsim", "", "alias: event grid (arrival x batching x ranks)"),
    ("cogsim", "", "alias: coupled grid (time-to-solution)"),
    ("fabric", "", "alias: pooled-vs-local crossover on the cog grid"),
    ("control", "", "control-plane resilience study (failures, degrade, autoscaler)"),
    ("scale", "", "fluid-tier scale-out study: pooled-vs-local crossover at 64-16384 ranks"),
    ("trace", "", "run one pooled cog cell with the flight recorder armed"),
    ("info", "", "show manifest/runtime info"),
];

fn spec_for(cmd: &str, name: &str) -> Option<&'static FlagSpec> {
    FLAGS.iter().find(|f| f.name == name && f.cmds.contains(&cmd))
}

fn print_usage() {
    println!(
        "repro — disaggregated CogSim inference (Wyatt et al., CS.DC 2021 reproduction)\n\nUSAGE:"
    );
    for (cmd, positional, desc) in COMMANDS {
        let pos = if positional.is_empty() { String::new() } else { format!(" {positional}") };
        println!("  repro {cmd}{pos} — {desc}");
        for f in FLAGS.iter().filter(|f| f.cmds.contains(cmd)) {
            match f.kind {
                FlagKind::Bool => println!("      [--{}]  {}", f.name, f.help),
                _ => println!("      [--{} {}]  {}", f.name, f.default, f.help),
            }
        }
    }
    println!(
        "\nThe grid modes sweep the pooled fabric's oversubscription and the\n\
         pool's fleet composition; `repro scenario --list` prints every axis\n\
         with its defaults.  `repro fabric` runs the focused\n\
         pooled-vs-node-local time-to-solution crossover sweep."
    );
}

/// Parsed arguments for one subcommand, validated against [`FLAGS`].
struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(cmd: &str, argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let Some(spec) = spec_for(cmd, key) else {
                    let valid: Vec<&str> = FLAGS
                        .iter()
                        .filter(|f| f.cmds.contains(&cmd))
                        .map(|f| f.name)
                        .collect();
                    bail!("unknown flag --{key} for `repro {cmd}` (valid: {valid:?})");
                };
                // A repeated flag is a hard error: silently letting
                // the last occurrence win hides typos in long sweep
                // command lines.
                let value = if spec.kind == FlagKind::Bool {
                    i += 1;
                    "true".to_string()
                } else {
                    let value = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                    i += 2;
                    value.clone()
                };
                if flags.insert(key.to_string(), value).is_some() {
                    bail!("flag --{key} given more than once for `repro {cmd}`");
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { cmd: cmd.to_string(), positional, flags })
    }

    /// The flag's value, falling back to its declared default.
    fn get(&self, key: &str) -> String {
        match self.flags.get(key) {
            Some(v) => v.clone(),
            None => spec_for(&self.cmd, key)
                .unwrap_or_else(|| panic!("flag --{key} not declared for `{}`", self.cmd))
                .default
                .to_string(),
        }
    }

    fn get_usize(&self, key: &str) -> Result<usize> {
        let v = self.get(key);
        // `str::parse` rejects trailing garbage ("32x"); keep it a
        // hard error that names the offending flag.
        v.parse().with_context(|| format!("flag --{key}: not an integer: {v:?}"))
    }

    fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// Comma-separated values of a `FlagKind::List` flag.
    fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    fn get_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get_list(key)
            .iter()
            .map(|v| v.parse().with_context(|| format!("flag --{key}: not an integer: {v:?}")))
            .collect()
    }

    fn get_f64_list(&self, key: &str) -> Result<Vec<f64>> {
        self.get_list(key)
            .iter()
            .map(|v| v.parse().with_context(|| format!("flag --{key}: not a number: {v:?}")))
            .collect()
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print_usage();
        return Ok(());
    };
    if matches!(cmd, "help" | "--help" | "-h") {
        print_usage();
        return Ok(());
    }
    if !COMMANDS.iter().any(|(c, _, _)| *c == cmd) {
        bail!("unknown command {cmd:?} (try `repro help`)");
    }
    let args = Args::parse(cmd, &argv[1..])?;
    match cmd {
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "repro" => cmd_repro(&args),
        "scaling" => cmd_scaling(&args),
        "scenario" => cmd_scenario(&args),
        "campaign" => cmd_campaign(&args),
        "eventsim" => cmd_eventsim(&args),
        "cogsim" => cmd_cogsim(&args),
        "fabric" => cmd_fabric(&args),
        "control" => cmd_control(&args),
        "scale" => cmd_scale(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(&args),
        _ => unreachable!("command list checked above"),
    }
}

/// Write a JSON document, creating parent directories (shared by
/// every grid subcommand).
fn write_json_out(out: &str, json: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, json).with_context(|| format!("writing {out}"))?;
    eprintln!("wrote {out}");
    Ok(())
}

/// Wrap a trace-event array into the Chrome/Perfetto document shape.
fn chrome_doc(events: Vec<Value>) -> Value {
    let mut m = BTreeMap::new();
    m.insert("traceEvents".to_string(), Value::Array(events));
    Value::Object(m)
}

/// The `--timings` side-channel: per-cell wall-clock and event-volume
/// JSON, deliberately separate from the golden-pinned summary (wall
/// time is the one thing that may never enter it).
fn timings_json(result: &GridResult, timings: &[CellTiming], threads: usize) -> Value {
    let mut m = BTreeMap::new();
    m.insert("threads".to_string(), Value::Number(threads as f64));
    let cells: Vec<Value> = result
        .cells
        .iter()
        .zip(timings)
        .map(|(c, t)| {
            let mut cm = BTreeMap::new();
            cm.insert("cell".to_string(), Value::String(c.scenario.cell_key()));
            cm.insert("wall_ms".to_string(), Value::Number(t.wall_ms));
            cm.insert("events".to_string(), Value::Number(t.events as f64));
            cm.insert("events_per_s".to_string(), Value::Number(t.events_per_s));
            Value::Object(cm)
        })
        .collect();
    m.insert("cells".to_string(), Value::Array(cells));
    m.insert(
        "total_wall_ms".to_string(),
        Value::Number(timings.iter().map(|t| t.wall_ms).sum()),
    );
    Value::Object(m)
}

/// Run a grid, print its tables, write its JSON — the single
/// execution path behind `repro scenario` and every alias.  Cells run
/// on a work-stealing pool of `threads` workers (0 = all cores,
/// 1 = sequential); the output is byte-identical at any width.
/// `trace_out` non-empty arms the flight recorder on every
/// engine-backed cell and writes one merged Perfetto timeline (cells
/// at disjoint pid blocks); `timings_out` non-empty writes the
/// wall-clock side-channel.
fn execute_grid(
    grid: &Grid,
    out: &str,
    threads: usize,
    trace_out: &str,
    timings_out: &str,
) -> Result<GridResult> {
    let armed = !trace_out.is_empty();
    let (result, timings, recorders) = run_grid_threads_full(grid, threads, armed).split();
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(out, &json::write(&result.to_json()))?;
    if !timings_out.is_empty() {
        write_json_out(timings_out, &json::write(&timings_json(&result, &timings, threads)))?;
    }
    if armed {
        let mut events = Vec::new();
        for (i, rec) in recorders.iter().enumerate() {
            if let Some(rec) = rec {
                // 4 pids per cell (requests/devices/fabric/control);
                // block-of-8 keeps cells disjoint and leaves room
                events.extend(
                    rec.chrome_trace(&result.cells[i].scenario.cell_key(), i as u64 * 8),
                );
            }
        }
        write_json_out(trace_out, &json::write(&chrome_doc(events)))?;
    }
    println!("{} cells", result.cells.len());
    Ok(result)
}

// ---------------------------------------------------- grid commands

/// Parse one `--controls` spec, prefixing parse errors with the flag
/// name — [`ControlSpec::parse`] already restates the grammar, so the
/// user sees flag, clause, and grammar in one line.
fn parse_control_flag(c: &str) -> Result<ControlSpec> {
    ControlSpec::parse(c).map_err(|why| anyhow!("flag --controls: {why}"))
}

/// The declarative scenario grid, straight from the axis flags.
fn cmd_scenario(args: &Args) -> Result<()> {
    let mut axes = Axes::default();
    axes.kinds = args
        .get_list("kinds")
        .iter()
        .map(|k| Kind::parse(k).ok_or_else(|| anyhow!("unknown kind {k:?}")))
        .collect::<Result<_>>()?;
    axes.topologies = args
        .get_list("topologies")
        .iter()
        .map(|t| match t.as_str() {
            "local" => Ok(Topology::Local),
            "pooled" => Ok(Topology::Pooled),
            "hybrid" => Ok(Topology::Hybrid),
            other => bail!("unknown topology {other:?}"),
        })
        .collect::<Result<_>>()?;
    axes.fleets = args
        .get_list("fleets")
        .iter()
        .map(|f| {
            Fleet::parse(f).ok_or_else(|| anyhow!("unknown fleet {f:?} (default|<G>g<R>r)"))
        })
        .collect::<Result<_>>()?;
    axes.policies = args
        .get_list("policies")
        .iter()
        .map(|p| {
            Policy::ALL
                .iter()
                .find(|x| x.key() == p.as_str())
                .copied()
                .ok_or_else(|| anyhow!("unknown policy {p:?}"))
        })
        .collect::<Result<_>>()?;
    axes.rank_counts = args.get_usize_list("ranks")?;
    axes.arrivals = args
        .get_list("arrivals")
        .iter()
        .map(|a| match a.as_str() {
            "synchronized" => Ok(ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 }),
            "poisson" => Ok(ArrivalProcess::Poisson { rate_per_rank: 800.0 }),
            "closed_loop" => Ok(ArrivalProcess::ClosedLoop { think_s: 2e-3 }),
            other => bail!("unknown arrival {other:?}"),
        })
        .collect::<Result<_>>()?;
    axes.windows_us = args.get_f64_list("windows-us")?;
    axes.models_per_rank = args.get_usize_list("models")?;
    axes.swap_costs_s = args.get_f64_list("swaps-us")?.iter().map(|us| us * 1e-6).collect();
    axes.overlaps = args.get_f64_list("overlaps")?;
    axes.fabric_oversubs = args.get_f64_list("oversubs")?;
    axes.controls = args
        .get_list("controls")
        .iter()
        .map(|c| parse_control_flag(c))
        .collect::<Result<_>>()?;

    let mut knobs = Knobs::default();
    knobs.timesteps = args.get_usize("timesteps")?;
    knobs.horizon_s = args.get_usize("horizon-ms")? as f64 / 1e3;
    knobs.seed = args.get_usize("seed")? as u64;
    if knobs.timesteps == 0 || knobs.horizon_s <= 0.0 {
        bail!("--timesteps and --horizon-ms must be positive");
    }

    let mut grid = Grid { axes, knobs };
    if args.get_bool("smoke") {
        grid.axes.rank_counts.truncate(1);
        grid.knobs.timesteps = grid.knobs.timesteps.min(3);
        grid.knobs.horizon_s = grid.knobs.horizon_s.min(0.05);
    }

    // pre-flight every (cell, control) pair: an autoscaler whose
    // bounds don't fit a cell's hermit tier must surface as a named
    // CLI error before the sweep starts, not a mid-run abort
    for sc in grid.cells() {
        cogsim_disagg::harness::validate_cell_ctl(&sc, &grid.axes.control(sc.control))
            .map_err(|why| anyhow!("flag --controls: {why}"))?;
    }

    if args.get_bool("list") {
        println!("scenario grid axes (current values; change with the same-named flag):");
        for (name, values, help) in grid.axis_help() {
            println!("  --{name:<12} {values:<40} {help}");
        }
        println!(
            "shared knobs: timesteps {}  horizon {} ms  seed {}",
            grid.knobs.timesteps,
            grid.knobs.horizon_s * 1e3,
            grid.knobs.seed
        );
        println!("{} cells would run", grid.cells().len());
        return Ok(());
    }

    execute_grid(
        &grid,
        &args.get("out"),
        args.get_usize("threads")?,
        &args.get("trace"),
        &args.get("timings"),
    )?;
    Ok(())
}

/// Alias: the analytic campaign as a pre-shaped grid.
fn cmd_campaign(args: &Args) -> Result<()> {
    let cfg = CampaignConfig {
        ranks: args.get_usize("ranks")?,
        zones_per_rank: args.get_usize("zones")?,
        timesteps: args.get_usize("timesteps")?,
        ..Default::default()
    };
    let result = execute_grid(
        &cfg.grid(),
        &args.get("out"),
        args.get_usize("threads")?,
        &args.get("trace"),
        &args.get("timings"),
    )?;

    // The headline comparison: does state-aware routing beat blind
    // round-robin on tail latency in the hybrid topology?
    let cell = |policy: Policy| {
        result
            .find(|s| s.topology == Topology::Hybrid && s.policy == policy && s.oversub == 1.0)
            .and_then(|c| c.analytic().map(|s| s.hydra.p99_s))
            .expect("campaign ran every cell")
    };
    let la = cell(Policy::LatencyAware);
    let rr = cell(Policy::RoundRobin);
    println!(
        "hybrid Hydra p99: latency-aware {:.1} us vs round-robin {:.1} us ({})",
        la * 1e6,
        rr * 1e6,
        if la < rr { "latency-aware wins" } else { "round-robin wins" }
    );
    Ok(())
}

/// Alias: the event grid (arrival x batching x ranks).
fn cmd_eventsim(args: &Args) -> Result<()> {
    let mut cfg = EventCampaignConfig::default();
    let horizon_ms = args.get_usize("horizon-ms")?;
    if horizon_ms == 0 {
        bail!("--horizon-ms must be positive");
    }
    cfg.horizon_s = horizon_ms as f64 / 1e3;
    cfg.seed = args.get_usize("seed")? as u64;
    let result = execute_grid(
        &cfg.grid(),
        &args.get("out"),
        args.get_usize("threads")?,
        &args.get("trace"),
        &args.get("timings"),
    )?;

    // The headline: under bursty 64-rank arrivals on the pooled
    // topology, does the dynamic-batching window shrink tail latency?
    let ranks = *cfg.rank_counts.last().expect("rank sweep is non-empty");
    let windows = (cfg.windows_us.first().copied(), cfg.windows_us.last().copied());
    if let (Some(w_off), Some(w_on)) = windows {
        let cell = |window_us: f64| {
            result
                .find(|s| {
                    s.topology == Topology::Pooled
                        && s.policy == Policy::LatencyAware
                        && s.arrival.key() == "synchronized"
                        && s.ranks == ranks
                        && s.window_us == window_us
                        && s.oversub == 1.0
                })
                .and_then(|c| c.event().map(|s| s.latency.p99_s))
        };
        if let (Some(off), Some(on)) = (cell(w_off), cell(w_on)) {
            println!(
                "pooled {ranks}-rank bursty p99: window {w_on} us {:.1} us vs window {w_off} us \
                 {:.1} us ({})",
                on * 1e6,
                off * 1e6,
                if on < off { "batching wins the tail" } else { "batching does not win here" }
            );
        }
    }
    Ok(())
}

/// Alias: the coupled grid (time-to-solution).
fn cmd_cogsim(args: &Args) -> Result<()> {
    let mut cfg = CogCampaignConfig::default();
    cfg.rank_counts = vec![args.get_usize("ranks")?];
    cfg.models_per_rank = vec![args.get_usize("models")?];
    cfg.timesteps = args.get_usize("timesteps")?;
    cfg.seed = args.get_usize("seed")? as u64;
    if args.get_bool("smoke") {
        // CI-sized: one topology, two policies, three steps.
        cfg.topologies = vec![Topology::Pooled];
        cfg.policies = vec![Policy::RoundRobin, Policy::ModelAffinity];
        cfg.timesteps = cfg.timesteps.min(3);
        cfg.overlaps = vec![0.0];
        cfg.fabric_oversubs = vec![1.0, 8.0];
    }
    if cfg.timesteps == 0 {
        bail!("--timesteps must be positive");
    }
    let result = execute_grid(
        &cfg.grid(),
        &args.get("out"),
        args.get_usize("threads")?,
        &args.get("trace"),
        &args.get("timings"),
    )?;

    // The headline: once swapping weights costs more than serving a
    // request, sticky model-affinity routing must beat blind
    // round-robin on time-to-solution (shared pool, serial coupling).
    let ranks = cfg.rank_counts[0];
    let models = cfg.models_per_rank[0];
    let swap = *cfg.swap_costs_s.last().expect("swap sweep is non-empty");
    let cell = |policy: Policy| {
        result
            .find(|s| {
                s.topology == Topology::Pooled
                    && s.policy == policy
                    && s.ranks == ranks
                    && s.models == models
                    && s.swap_s == swap
                    && s.overlap == 0.0
                    && s.oversub == 1.0
            })
            .and_then(|c| c.cog().map(|s| s.time_to_solution_s))
    };
    if let (Some(aff), Some(rr)) = (cell(Policy::ModelAffinity), cell(Policy::RoundRobin)) {
        println!(
            "pooled TTS at swap {:.0} us: model-affinity {:.2} ms vs round-robin {:.2} ms ({})",
            swap * 1e6,
            aff * 1e3,
            rr * 1e3,
            if aff < rr { "affinity wins" } else { "affinity does not win here" }
        );
    }
    Ok(())
}

/// Alias: contention crossover on the flow-level fabric — pooled vs
/// node-local time-to-solution across rank count × oversubscription.
fn cmd_fabric(args: &Args) -> Result<()> {
    let smoke = args.get_bool("smoke");
    let mut cfg = CogCampaignConfig {
        topologies: vec![Topology::Local, Topology::Pooled],
        policies: vec![Policy::LatencyAware],
        rank_counts: if smoke { vec![4, 32] } else { vec![4, 8, 16, 32] },
        models_per_rank: vec![8],
        swap_costs_s: vec![0.0],
        overlaps: vec![0.0],
        fabric_oversubs: if smoke { vec![1.0, 8.0] } else { vec![1.0, 2.0, 4.0, 8.0] },
        ..Default::default()
    };
    cfg.timesteps = args.get_usize("timesteps")?;
    if smoke {
        cfg.timesteps = cfg.timesteps.min(3);
    }
    cfg.seed = args.get_usize("seed")? as u64;
    if cfg.timesteps == 0 {
        bail!("--timesteps must be positive");
    }
    let result = execute_grid(
        &cfg.grid(),
        &args.get("out"),
        args.get_usize("threads")?,
        &args.get("trace"),
        &args.get("timings"),
    )?;

    // The headline: at what (rank count, oversubscription) does the
    // shared pool lose to per-rank local GPUs on time-to-solution?
    let policy = cfg.policies[0];
    let tts = |topology: Topology, ranks: usize, oversub: f64| {
        result
            .find(|s| {
                s.topology == topology
                    && s.policy == policy
                    && s.ranks == ranks
                    && s.oversub == oversub
            })
            .and_then(|c| c.cog().map(|s| s.time_to_solution_s))
            .expect("cell ran")
    };
    let mut crossover: Option<(usize, f64)> = None;
    println!("pooled-vs-local TTS (ms), policy {}:", policy.key());
    for &ranks in &cfg.rank_counts {
        let local_s = tts(Topology::Local, ranks, 1.0);
        let mut row = format!("  ranks {ranks:>3}: local {:>8.2}  pooled", local_s * 1e3);
        for &oversub in &cfg.fabric_oversubs {
            let pooled_s = tts(Topology::Pooled, ranks, oversub);
            let behind = pooled_s > local_s;
            row.push_str(&format!(
                " {oversub}:1={:.2}{}",
                pooled_s * 1e3,
                if behind { "*" } else { "" }
            ));
            if behind && crossover.is_none() {
                crossover = Some((ranks, oversub));
            }
        }
        println!("{row}");
    }
    match crossover {
        Some((ranks, oversub)) => println!(
            "pooled falls behind node-local from {ranks} ranks at {oversub}:1 \
             oversubscription (* = pooled slower)"
        ),
        None => println!("pooled never falls behind node-local in this sweep"),
    }
    Ok(())
}

/// The control-plane resilience study: a fixed seven-cell campaign
/// (local/pooled × static/leave, plus pooled degrade / rank-failure /
/// autoscaler cells) pinning the dynamic-fleet headline.
fn cmd_control(args: &Args) -> Result<()> {
    let cfg = ControlCampaignConfig {
        ranks: args.get_usize("ranks")?,
        timesteps: args.get_usize("timesteps")?,
        seed: args.get_usize("seed")? as u64,
        ..Default::default()
    };
    if cfg.ranks == 0 || cfg.timesteps == 0 {
        bail!("--ranks and --timesteps must be positive");
    }
    let result = run_control_campaign(&cfg);
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&args.get("out"), &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline: the pooled fleet degrades more gracefully than
    // node-local under one-backend loss, and the reactive autoscaler
    // holds TTS within a bounded factor of static provisioning.
    let loss_local = result.loss_ratio("local");
    let loss_pooled = result.loss_ratio("pooled");
    println!(
        "one-backend loss TTS ratio: local x{loss_local:.3} vs pooled x{loss_pooled:.3} ({})",
        if loss_pooled < loss_local {
            "pooled degrades more gracefully"
        } else {
            "pooled does not win here"
        }
    );
    let auto = result.autoscaler_factor();
    println!(
        "autoscaler TTS vs static provisioning: x{auto:.3} (bound x{:.1})",
        cogsim_disagg::harness::report::AUTOSCALER_BOUND
    );
    Ok(())
}

/// The fluid-tier scale-out study: leadership-class rank counts
/// against pool sizes, solved in closed form — the whole campaign is
/// milliseconds of wall time, which is the point of the fluid tier.
fn cmd_scale(args: &Args) -> Result<()> {
    let cfg = if args.get_bool("smoke") {
        ScaleCampaignConfig::smoke()
    } else {
        ScaleCampaignConfig::default()
    };
    let started = Instant::now();
    // Anchors included: the event engine re-runs the swap-free pooled
    // cells at the anchor rank counts next to the fluid solutions
    // (seconds, not the milliseconds the fluid sweep itself takes).
    let result = run_scale_campaign_with_anchors(&cfg);
    let elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    for table in result.tables() {
        println!("{}", table.render());
    }
    write_json_out(&args.get("out"), &cogsim_disagg::util::json::write(&result.to_json()))?;

    // The headline: where does the pooled tier catch the node-local
    // baseline as the machine grows?
    let largest_pool = *cfg.pool_sizes.last().expect("pool sweep is non-empty");
    for row in &result.rows {
        match row.crossover_pool {
            Some(p) => println!(
                "{:>6} ranks: pooled matches node-local from pool size {p}",
                row.ranks
            ),
            None => println!(
                "{:>6} ranks: node-local wins up to pool size {largest_pool}",
                row.ranks
            ),
        }
    }
    for a in &result.anchors {
        println!(
            "{:>6} ranks: event-engine anchor, fluid TTS {:+.2}% vs event (bound ±{:.0}%)",
            a.ranks,
            a.tts_error() * 1e2,
            cogsim_disagg::fluid::ANCHOR_TTS_BOUND * 1e2
        );
    }
    let cells = result.rows.len() * (1 + cfg.pool_sizes.len()) + result.anchors.len();
    println!("{cells} cells in {elapsed_ms:.1} ms");
    Ok(())
}

// --------------------------------------------------- serving + misc

/// Start the disaggregated inference server.
fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts");
    let addr = args.get("addr");
    let materials = args.get_usize("materials")?;
    let workers = args.get_usize("workers")?;

    let engine = if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("loading artifacts from {artifacts}/ ...");
        Engine::load(&artifacts, None)?
    } else {
        eprintln!(
            "no {artifacts}/manifest.json — serving the deterministic \
             simulated engine (run `make artifacts` for PJRT execution)"
        );
        Engine::sim_reference()
    };
    let mut registry = Registry::new();
    registry.register_materials("hermit", materials);
    registry.register("mir", "mir");
    registry.register("mir_noln", "mir_noln");

    let config = CoordinatorConfig {
        workers,
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::start(engine, registry, config)?);
    let server = Server::serve(Arc::clone(&coordinator), &addr)?;
    eprintln!(
        "serving {} instances on {} ({} workers)",
        coordinator.registry().len(),
        server.addr(),
        workers
    );
    eprintln!("instances: {:?}", coordinator.registry().instance_names());

    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive a server like one MPI rank.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr");
    let model = args.get("model");
    let batch = args.get_usize("batch")?;
    let requests = args.get_usize("requests")?;
    let pipeline = args.get_usize("pipeline")?.max(1);

    let client = Client::connect(addr.as_str())?;
    let input_elems = if model.starts_with("mir") { 48 * 48 } else { 42 };
    let mut rng = Rng::new(7);
    let payload = rng.normal_vec(batch * input_elems);

    // warm-up (paper: 10 mini-batches)
    for _ in 0..10 {
        client.infer(&model, batch, &payload)?;
    }

    let mut latency = LatencyRecorder::new();
    let started = Instant::now();
    if pipeline == 1 {
        for _ in 0..requests {
            let t0 = Instant::now();
            client.infer(&model, batch, &payload)?;
            latency.record(t0.elapsed());
        }
    } else {
        // pipelined: keep `pipeline` requests in flight (paper §V-A)
        let mut inflight = std::collections::VecDeque::new();
        for _ in 0..requests {
            while inflight.len() >= pipeline {
                let (t0, rx): (Instant, _) = inflight.pop_front().unwrap();
                client.recv(rx)?;
                latency.record(t0.elapsed());
            }
            inflight.push_back((Instant::now(), client.submit(&model, batch, &payload)?));
        }
        for (t0, rx) in inflight {
            client.recv(rx)?;
            latency.record(t0.elapsed());
        }
    }
    let wall = started.elapsed().as_secs_f64();

    println!("model            {model}");
    println!("mini-batch       {batch}");
    println!("requests         {requests} (pipeline depth {pipeline})");
    println!("mean latency     {:.3} ms", latency.mean_s() * 1e3);
    println!(
        "p50/p95/p99      {:.3} / {:.3} / {:.3} ms",
        latency.p50_s() * 1e3,
        latency.p95_s() * 1e3,
        latency.p99_s() * 1e3
    );
    println!(
        "throughput       {:.0} samples/s",
        (requests * batch) as f64 / wall
    );
    Ok(())
}

/// Regenerate paper figures.
fn cmd_repro(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let out_dir = args.get("out");
    std::fs::create_dir_all(&out_dir)?;

    let ids: Vec<&str> = if which == "all" {
        FIGURES.to_vec()
    } else {
        vec![which]
    };
    for id in ids {
        let fig = run_figure(id)?;
        println!("================ {} — {}", fig.id, fig.caption);
        for (i, table) in fig.tables.iter().enumerate() {
            println!("{}", table.render());
            let suffix = if fig.tables.len() > 1 {
                format!("{}_{}", fig.id, (b'a' + i as u8) as char)
            } else {
                fig.id.to_string()
            };
            let path = format!("{out_dir}/{suffix}.csv");
            std::fs::write(&path, table.to_csv())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

/// Scaling analysis: ranks-per-DataScale frontier (paper SVI).
fn cmd_scaling(args: &Args) -> Result<()> {
    let max_ranks = args.get_usize("max-ranks")?;
    let step_ms = args.get_usize("step-ms")?;
    let slo_ms = args.get_usize("slo-ms")?;
    let scenario = cogsim_disagg::harness::scaling::Scenario {
        step_s: step_ms as f64 / 1e3,
        latency_slo_s: slo_ms as f64 / 1e3,
        ..Default::default()
    };
    let mut counts = Vec::new();
    let mut r = 1usize;
    while r <= max_ranks {
        counts.push(r);
        r *= 2;
    }
    let (table, max_ok) = cogsim_disagg::harness::scaling::sweep(&scenario, &counts);
    println!("{}", table.render());
    match max_ok {
        Some(n) => println!("max SLO-feasible ranks on one SN10-8 node: {n}"),
        None => println!("no feasible rank count under this SLO"),
    }
    Ok(())
}

/// Run one pooled cog cell with the flight recorder armed: write the
/// Perfetto timeline + attribution JSON, print the attribution table,
/// and hard-fail unless the recorder's per-device busy integrals
/// reconcile with the engine's own service accounting to 1e-9 s.
fn cmd_trace(args: &Args) -> Result<()> {
    let smoke = args.get_bool("smoke");
    let mut ranks = args.get_usize("ranks")?;
    let mut timesteps = args.get_usize("timesteps")?;
    if smoke {
        ranks = ranks.min(8);
        timesteps = timesteps.min(3);
    }
    if ranks == 0 || timesteps == 0 {
        bail!("--ranks and --timesteps must be positive");
    }
    let sc = Scenario {
        kind: Kind::Cog,
        topology: Topology::Pooled,
        fleet: Fleet::DefaultPool,
        policy: Policy::LeastOutstanding,
        ranks,
        arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
        window_us: 0.0,
        models: 8,
        swap_s: args.get_usize("swap-us")? as f64 * 1e-6,
        overlap: 0.0,
        oversub: 2.0,
        control: 0,
    };
    let knobs = Knobs { timesteps, seed: args.get_usize("seed")? as u64, ..Knobs::default() };
    let run = try_run_cell_full(&sc, &knobs, &ControlSpec::static_(), true)
        .map_err(|why| anyhow!(why))?;
    let rec = run.recorder.expect("armed cog cells carry the recorder");

    let mut max_err = 0.0f64;
    for d in 0..rec.devices() {
        let engine = run.device_busy_s.get(d).copied().unwrap_or(0.0);
        max_err = max_err.max((rec.busy_integral_s(d) - engine).abs());
    }
    if max_err > 1e-9 {
        bail!("flight-recorder busy integrals diverge from the engine by {max_err:.3e} s");
    }

    let out = args.get("out");
    let stem = out.strip_suffix(".json").unwrap_or(&out);
    let trace_path = format!("{stem}.trace.json");
    write_json_out(&trace_path, &json::write(&chrome_doc(rec.chrome_trace(&sc.cell_key(), 0))))?;
    write_json_out(&out, &json::write(&rec.attribution()))?;

    let horizon_s = rec.horizon_s();
    println!(
        "flight recorder: {} — {} spans, {} markers, busy reconciled to {max_err:.1e} s",
        sc.cell_key(),
        rec.spans().len(),
        rec.markers().len()
    );
    println!("  {:<24} {:>10} {:>8} {:>7}", "device", "busy_ms", "batches", "util");
    for d in 0..rec.devices() {
        let busy_s = rec.busy_integral_s(d);
        println!(
            "  {:<24} {:>10.3} {:>8} {:>6.1}%",
            rec.device_name(d),
            busy_s * 1e3,
            rec.busy_intervals(d).len(),
            if horizon_s > 0.0 { busy_s / horizon_s * 100.0 } else { 0.0 }
        );
    }
    println!(
        "  gate wait {:.3} ms over {} residency misses; horizon {:.3} ms",
        rec.gate_wait_total_s() * 1e3,
        rec.swap_misses(),
        horizon_s * 1e3
    );
    if let Some(cog) = run.result.cog() {
        println!("  time-to-solution {:.3} ms", cog.time_to_solution_s * 1e3);
    }
    println!("open {trace_path} in ui.perfetto.dev (or chrome://tracing) for the timeline");
    Ok(())
}

/// Show manifest/runtime info.
fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts");
    let manifest = cogsim_disagg::runtime::Manifest::load(&artifacts)?;
    println!("artifacts: {}", manifest.dir.display());
    println!("dtype {}  seed {}", manifest.dtype, manifest.seed);
    for (name, spec) in &manifest.models {
        println!(
            "  {name:<10} params {:>9}  in {:?} out {:?}  batches {:?}",
            spec.param_count,
            spec.input_shape,
            spec.output_shape,
            spec.batch_ladder()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Vec<String> {
        words.iter().map(|w| w.to_string()).collect()
    }

    #[test]
    fn repeated_flag_is_a_hard_error_naming_the_flag() {
        let err = Args::parse("cogsim", &argv(&["--ranks", "4", "--ranks", "8"]))
            .expect_err("duplicate flag must not silently last-win");
        let msg = format!("{err:#}");
        assert!(msg.contains("--ranks"), "error must name the flag: {msg}");
        assert!(msg.contains("more than once"), "error must say why: {msg}");
    }

    #[test]
    fn repeated_bool_flag_is_also_rejected() {
        let err = Args::parse("cogsim", &argv(&["--smoke", "--smoke"]))
            .expect_err("duplicate bool flag must error");
        assert!(format!("{err:#}").contains("--smoke"));
    }

    #[test]
    fn trailing_garbage_in_numeric_flag_names_the_flag() {
        let args = Args::parse("cogsim", &argv(&["--ranks", "32x"])).expect("parse stage is lexical");
        let err = args.get_usize("ranks").expect_err("'32x' is not an integer");
        let msg = format!("{err:#}");
        assert!(msg.contains("--ranks") && msg.contains("32x"), "{msg}");
    }

    #[test]
    fn trailing_garbage_in_numeric_list_names_the_flag() {
        let args = Args::parse("scenario", &argv(&["--ranks", "4,32x"])).expect("lexical parse");
        let err = args.get_usize_list("ranks").expect_err("'32x' is not an integer");
        let msg = format!("{err:#}");
        assert!(msg.contains("--ranks") && msg.contains("32x"), "{msg}");
    }

    #[test]
    fn malformed_control_spec_is_a_named_cli_error() {
        for bad in ["leave:0", "wobble:1@3", "auto:9", "degrade:zero@100"] {
            let err = parse_control_flag(bad).expect_err("malformed spec must error");
            let msg = format!("{err:#}");
            assert!(msg.contains("--controls"), "error must name the flag: {msg}");
            assert!(msg.contains("grammar"), "error must restate the grammar: {msg}");
        }
    }

    #[test]
    fn empty_control_spec_is_a_named_cli_error() {
        let err = parse_control_flag("").expect_err("empty spec must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("--controls") && msg.contains("empty spec"), "{msg}");
        // a stray '+' leaves an empty clause
        let err = parse_control_flag("leave:0@100+").expect_err("stray '+' must error");
        assert!(format!("{err:#}").contains("empty clause"));
    }

    #[test]
    fn duplicate_control_clause_is_a_named_cli_error() {
        let err = parse_control_flag("leave:0@100+leave:0@100")
            .expect_err("duplicate clause must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("--controls") && msg.contains("duplicate"), "{msg}");
        // two autoscalers cannot combine even when spelled differently
        let err = parse_control_flag("auto:2:1-4:100:1000+auto:1:1-2:100:1000")
            .expect_err("second auto: clause must error");
        assert!(format!("{err:#}").contains("auto"), "names the clause");
    }

    #[test]
    fn well_formed_control_spec_still_parses() {
        let spec = parse_control_flag("leave:0@30000+join:0@60000+auto:2:1-4:100:2000")
            .expect("valid combined spec");
        assert_eq!(spec.trace.len(), 2);
        assert!(spec.autoscaler.is_some());
    }

    #[test]
    fn distinct_flags_still_parse() {
        let args =
            Args::parse("cogsim", &argv(&["--ranks", "8", "--models", "4", "--smoke"])).unwrap();
        assert_eq!(args.get_usize("ranks").unwrap(), 8);
        assert_eq!(args.get_usize("models").unwrap(), 4);
        assert!(args.get_bool("smoke"));
        // Defaults still fill unset flags.
        assert_eq!(args.get_usize("threads").unwrap(), 0);
    }
}
