//! The [`Backend`] trait: one uniform surface over every accelerator
//! model in the crate — the analytic GPU model ([`crate::devices`]),
//! the RDU dataflow model ([`crate::rdu`]) — with a
//! [`crate::netsim::Link`] in front and virtual-time queue state.
//!
//! A backend answers three questions the router needs:
//!
//! * `latency_s(model, batch)` — how long one batch takes end to end
//!   (link round trip + device execution, empty queue);
//! * `throughput(model, batch)` — samples/s at that operating point;
//! * `queue_s()` — how much virtual work is already waiting.
//!
//! Occupancy accounting follows the paper's async double-buffering:
//! a remote batch holds the backend for its execute time plus only
//! the *non-overlapped* fraction of the link overhead (`remote_period`
//! semantics, Fig. 16), while the requester still waits the full
//! round trip (Fig. 15).

use crate::devices::{Api, Gpu, GpuModel, ModelProfile};
use crate::netsim::{payload_bytes, Link};
use crate::rdu::{RduApi, RduModel};

/// A schedulable inference backend: device model + link + queue.
pub trait Backend: Send {
    /// Display/report name (e.g. `gpu/rank0`, `rdu/pool1`).
    fn name(&self) -> &str;

    /// The link requests traverse to reach this backend.
    fn link(&self) -> &Link;

    /// Pure device execution time for one batch, seconds.
    fn execute_s(&self, model: &ModelProfile, batch: usize) -> f64;

    /// Outstanding virtual work queued on this backend, seconds.
    fn queue_s(&self) -> f64;

    /// Add `s` seconds of work to the queue.
    fn add_queue_s(&mut self, s: f64);

    /// Let `dt` seconds of virtual time pass (the queue drains).
    fn drain_queue_s(&mut self, dt: f64);

    /// Link round-trip overhead for one batch, seconds.
    fn link_overhead_s(&self, model: &ModelProfile, batch: usize) -> f64 {
        self.link()
            .rtt_overhead_s(payload_bytes(model.input_elems, model.output_elems, batch))
    }

    /// Empty-queue end-to-end latency: link round trip + execution.
    fn latency_s(&self, model: &ModelProfile, batch: usize) -> f64 {
        self.link_overhead_s(model, batch) + self.execute_s(model, batch)
    }

    /// Samples/s at this batch size (empty queue).
    fn throughput(&self, model: &ModelProfile, batch: usize) -> f64 {
        batch as f64 / self.latency_s(model, batch)
    }

    /// How long one batch occupies the backend: execution plus the
    /// non-overlapped link share (double-buffered clients hide the
    /// rest behind device execution — the paper's throughput trick).
    fn occupancy_s(&self, model: &ModelProfile, batch: usize) -> f64 {
        self.execute_s(model, batch)
            + self.link_overhead_s(model, batch) * (1.0 - self.link().async_overlap)
    }
}

/// A GPU behind an API configuration (node-local by default).
#[derive(Debug, Clone)]
pub struct GpuBackend {
    name: String,
    gpu: Gpu,
    api: Api,
    link: Link,
    queue_s: f64,
}

impl GpuBackend {
    /// A node-local GPU (zero-cost link, the paper's GPU convention).
    pub fn node_local(name: impl Into<String>, gpu: Gpu, api: Api) -> GpuBackend {
        GpuBackend { name: name.into(), gpu, api, link: Link::local(), queue_s: 0.0 }
    }

    /// A GPU reached over a link (a pooled GPU fleet).
    pub fn remote(name: impl Into<String>, gpu: Gpu, api: Api, link: Link) -> GpuBackend {
        GpuBackend { name: name.into(), gpu, api, link, queue_s: 0.0 }
    }
}

impl Backend for GpuBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn link(&self) -> &Link {
        &self.link
    }

    fn execute_s(&self, model: &ModelProfile, batch: usize) -> f64 {
        GpuModel::new(self.gpu.clone(), self.api, model.clone()).latency_s(batch)
    }

    fn queue_s(&self) -> f64 {
        self.queue_s
    }

    fn add_queue_s(&mut self, s: f64) {
        self.queue_s += s;
    }

    fn drain_queue_s(&mut self, dt: f64) {
        self.queue_s = (self.queue_s - dt).max(0.0);
    }
}

/// An RDU tile group behind a SambaFlow API (remote by default — the
/// disaggregated pool of the paper).
#[derive(Debug, Clone)]
pub struct RduBackend {
    name: String,
    tiles: usize,
    api: RduApi,
    link: Link,
    queue_s: f64,
}

impl RduBackend {
    /// An RDU tile group across the Infiniband link (the paper's
    /// disaggregated configuration).
    pub fn disaggregated(name: impl Into<String>, tiles: usize, api: RduApi) -> RduBackend {
        Self::with_link(name, tiles, api, Link::infiniband_cx6())
    }

    /// A node-local RDU tile group (the paper's local baseline).
    pub fn node_local(name: impl Into<String>, tiles: usize, api: RduApi) -> RduBackend {
        Self::with_link(name, tiles, api, Link::local())
    }

    pub fn with_link(
        name: impl Into<String>,
        tiles: usize,
        api: RduApi,
        link: Link,
    ) -> RduBackend {
        assert!((1..=4).contains(&tiles), "an SN10 RDU has 4 tiles");
        RduBackend { name: name.into(), tiles, api, link, queue_s: 0.0 }
    }

    pub fn tiles(&self) -> usize {
        self.tiles
    }
}

impl Backend for RduBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn link(&self) -> &Link {
        &self.link
    }

    fn execute_s(&self, model: &ModelProfile, batch: usize) -> f64 {
        RduModel::new(model.clone(), self.tiles, self.api).latency_best_s(batch)
    }

    fn queue_s(&self) -> f64 {
        self.queue_s
    }

    fn add_queue_s(&mut self, s: f64) {
        self.queue_s += s;
    }

    fn drain_queue_s(&mut self, dt: f64) {
        self.queue_s = (self.queue_s - dt).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profiles;

    #[test]
    fn local_gpu_has_no_link_overhead() {
        let b = GpuBackend::node_local("gpu0", Gpu::a100(), Api::TrtCudaGraphs);
        let p = profiles::hermit();
        assert_eq!(b.link_overhead_s(&p, 256), 0.0);
        assert_eq!(b.latency_s(&p, 256), b.execute_s(&p, 256));
        assert_eq!(b.occupancy_s(&p, 256), b.execute_s(&p, 256));
    }

    #[test]
    fn disaggregated_rdu_pays_the_link_but_hides_half() {
        let b = RduBackend::disaggregated("rdu0", 4, RduApi::CppOptimized);
        let p = profiles::hermit();
        let overhead = b.link_overhead_s(&p, 1024);
        assert!(overhead > 0.0);
        assert!(b.latency_s(&p, 1024) > b.execute_s(&p, 1024));
        // double buffering: occupancy strictly between execute-only
        // and the full round trip
        let occ = b.occupancy_s(&p, 1024);
        assert!(occ > b.execute_s(&p, 1024));
        assert!(occ < b.latency_s(&p, 1024));
    }

    #[test]
    fn more_tiles_execute_faster() {
        let p = profiles::hermit();
        let small = RduBackend::disaggregated("rdu-2t", 2, RduApi::CppOptimized);
        let big = RduBackend::disaggregated("rdu-4t", 4, RduApi::CppOptimized);
        for batch in [64usize, 1024, 16384] {
            assert!(big.execute_s(&p, batch) < small.execute_s(&p, batch), "{batch}");
        }
    }

    #[test]
    fn queue_accounting() {
        let mut b = GpuBackend::node_local("gpu0", Gpu::a100(), Api::NaivePyTorch);
        assert_eq!(b.queue_s(), 0.0);
        b.add_queue_s(3e-3);
        b.add_queue_s(1e-3);
        assert!((b.queue_s() - 4e-3).abs() < 1e-15);
        b.drain_queue_s(2.5e-3);
        assert!((b.queue_s() - 1.5e-3).abs() < 1e-15);
        b.drain_queue_s(10.0);
        assert_eq!(b.queue_s(), 0.0); // never negative
    }

    #[test]
    fn zero_sample_requests_never_nan_the_transfer_math() {
        // Regression for the Link::local() INFINITY bandwidth audit:
        // a zero-sample request has a zero-byte payload; every
        // latency/occupancy figure must stay finite (non-NaN) on both
        // local and remote links.
        let p = profiles::hermit();
        let local = GpuBackend::node_local("gpu0", Gpu::a100(), Api::TrtCudaGraphs);
        assert_eq!(local.link_overhead_s(&p, 0), 0.0);
        assert!(local.latency_s(&p, 0).is_finite());
        assert!(local.occupancy_s(&p, 0).is_finite());
        let remote = GpuBackend::remote(
            "gpu-far",
            Gpu::a100(),
            Api::TrtCudaGraphs,
            crate::netsim::Link::infiniband_cx6(),
        );
        let over = remote.link_overhead_s(&p, 0);
        assert!(over.is_finite() && over > 0.0, "fixed per-message cost remains");
        assert!(remote.latency_s(&p, 0).is_finite());
    }

    #[test]
    fn throughput_consistent_with_latency() {
        let b = RduBackend::disaggregated("rdu0", 4, RduApi::CppOptimized);
        let p = profiles::hermit();
        let t = b.throughput(&p, 4096);
        assert!((t - 4096.0 / b.latency_s(&p, 4096)).abs() < 1e-9);
    }
}
