//! Routing policies: how the [`super::Cluster`] picks a backend for
//! each request.  Four policies span the design space the paper's §VI
//! opens (one shared accelerator vs many heterogeneous ones):
//!
//! * **round-robin** — cycle the fleet, blind to state;
//! * **least-outstanding-work** — argmin of queued seconds;
//! * **model-affinity** — sticky per-instance routing (a material's
//!   requests always revisit the backend that holds its weights —
//!   exploits the registry/weight-residency structure);
//! * **latency-aware** — argmin of `queue + link + execute` for this
//!   exact (model, batch): the only policy that sees heterogeneity.

use std::collections::BTreeMap;

use crate::devices::ModelProfile;

use super::backend::Backend;

/// A pluggable routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    RoundRobin,
    LeastOutstanding,
    ModelAffinity,
    LatencyAware,
}

impl Policy {
    pub const ALL: [Policy; 4] = [
        Policy::RoundRobin,
        Policy::LeastOutstanding,
        Policy::ModelAffinity,
        Policy::LatencyAware,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastOutstanding => "least-outstanding",
            Policy::ModelAffinity => "model-affinity",
            Policy::LatencyAware => "latency-aware",
        }
    }

    /// Stable snake_case key for JSON artifacts.
    pub fn key(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round_robin",
            Policy::LeastOutstanding => "least_outstanding",
            Policy::ModelAffinity => "model_affinity",
            Policy::LatencyAware => "latency_aware",
        }
    }
}

/// Pick a backend index (from `candidates`, indices into `backends`)
/// for one request.  Deterministic: ties break on the lowest index.
/// Crate-visible so the event simulator ([`crate::eventsim`]) routes
/// its batches through *exactly* the same selection logic as the
/// analytic [`super::Cluster`] — the differential test depends on it.
///
/// The string-keyed map is the analytic cluster's convenience view;
/// the hot path ([`crate::simcore::Pipeline`]) resolves the instance
/// to a dense model id once at submit and calls [`select_slot`] with
/// that id's affinity slot directly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn select(
    policy: Policy,
    backends: &[Box<dyn Backend>],
    rr_cursor: &mut usize,
    affinity: &mut BTreeMap<String, usize>,
    candidates: &[usize],
    instance: &str,
    profile: &ModelProfile,
    batch: usize,
) -> usize {
    let mut slot = affinity.get(instance).copied();
    let idx = select_slot(policy, backends, rr_cursor, &mut slot, candidates, profile, batch);
    if let Some(parked) = slot {
        affinity.insert(instance.to_string(), parked);
    }
    idx
}

/// [`select`] with the instance's sticky-affinity entry passed as a
/// dense slot instead of a string-keyed map lookup.  Only
/// [`Policy::ModelAffinity`] reads or writes the slot.
pub(crate) fn select_slot(
    policy: Policy,
    backends: &[Box<dyn Backend>],
    rr_cursor: &mut usize,
    affinity_slot: &mut Option<usize>,
    candidates: &[usize],
    profile: &ModelProfile,
    batch: usize,
) -> usize {
    assert!(!candidates.is_empty(), "route with no candidate backends");
    match policy {
        Policy::RoundRobin => {
            // One shared dial for the whole cluster (classic L4
            // balancer semantics): blind by design, including across
            // candidate tiers.  State-aware spreading is what
            // LeastOutstanding / LatencyAware are for.
            let idx = candidates[*rr_cursor % candidates.len()];
            *rr_cursor += 1;
            idx
        }
        Policy::LeastOutstanding => least_queued(backends, candidates),
        Policy::ModelAffinity => {
            if let Some(idx) = *affinity_slot {
                if candidates.contains(&idx) {
                    return idx;
                }
            }
            // first sighting: park the instance on the least-loaded
            // candidate and stick to it
            let idx = least_queued(backends, candidates);
            *affinity_slot = Some(idx);
            idx
        }
        Policy::LatencyAware => {
            let mut best = candidates[0];
            let mut best_cost = f64::INFINITY;
            for &idx in candidates {
                let b = &backends[idx];
                let cost = b.queue_s() + b.latency_s(profile, batch);
                if cost < best_cost {
                    best = idx;
                    best_cost = cost;
                }
            }
            best
        }
    }
}

fn least_queued(backends: &[Box<dyn Backend>], candidates: &[usize]) -> usize {
    let mut best = candidates[0];
    let mut best_queue = f64::INFINITY;
    for &idx in candidates {
        let q = backends[idx].queue_s();
        if q < best_queue {
            best = idx;
            best_queue = q;
        }
    }
    best
}
