//! The multi-backend cluster layer — the crate's answer to the
//! paper's §VI scaling question, generalised: instead of *one* GPU
//! **or** *one* disaggregated DataScale, compose **N heterogeneous
//! backends** (analytic GPUs, RDU tile groups, each behind its own
//! link model) and route a CogSim request stream across them under a
//! pluggable policy.
//!
//! * [`backend`] — the [`Backend`] trait unifying
//!   [`crate::devices::GpuModel`], [`crate::rdu::RduModel`] and
//!   [`crate::netsim::Link`] behind `latency_s` / `throughput` /
//!   `queue_s`, plus the [`GpuBackend`] / [`RduBackend`] impls.
//! * [`policy`]  — four routing policies: round-robin,
//!   least-outstanding-work, model-affinity (sticky per-instance) and
//!   latency-aware (argmin of queue + link + execute).
//! * [`Cluster`] — virtual-time router: requests arrive at the
//!   cluster clock, wait behind the routed backend's queue, occupy it
//!   for the double-buffered period, and report their end-to-end
//!   latency.  Everything is deterministic — no wall clock — so
//!   scenario-grid sweeps ([`crate::harness::sweep`]) are byte-stable.
//!
//! The coordinator mirrors this layer on the serving path: registry
//! replica sets + [`crate::coordinator::RoutingPolicy`] route real
//! requests over real engine models the same way the cluster routes
//! simulated ones over analytic backends.

pub mod backend;
pub mod policy;

use std::collections::BTreeMap;

use crate::devices::ModelProfile;

pub use backend::{Backend, GpuBackend, RduBackend};
pub use policy::Policy;

/// Where one request went and what it cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Routed {
    /// Index of the chosen backend.
    pub backend: usize,
    /// Time spent waiting behind earlier work, seconds.
    pub wait_s: f64,
    /// End-to-end request latency (wait + link + execute), seconds.
    pub latency_s: f64,
    /// The link round-trip share of the latency, seconds.
    pub link_overhead_s: f64,
}

/// Per-backend accounting over a run.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendReport {
    pub name: String,
    pub requests: u64,
    pub samples: u64,
    /// Total seconds of occupancy routed to this backend.
    pub busy_s: f64,
    /// Queue depth at report time, seconds.
    pub queue_s: f64,
}

#[derive(Debug, Clone, Copy, Default)]
struct BackendStats {
    requests: u64,
    samples: u64,
    busy_s: f64,
}

/// N backends + a routing policy + a virtual clock.
pub struct Cluster {
    backends: Vec<Box<dyn Backend>>,
    policy: Policy,
    rr_cursor: usize,
    affinity: BTreeMap<String, usize>,
    stats: Vec<BackendStats>,
    clock_s: f64,
    last_completion_s: f64,
}

impl Cluster {
    pub fn new(backends: Vec<Box<dyn Backend>>, policy: Policy) -> Cluster {
        assert!(!backends.is_empty(), "a cluster needs at least one backend");
        let stats = vec![BackendStats::default(); backends.len()];
        Cluster {
            backends,
            policy,
            rr_cursor: 0,
            affinity: BTreeMap::new(),
            stats,
            clock_s: 0.0,
            last_completion_s: 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    pub fn backend_names(&self) -> Vec<String> {
        self.backends.iter().map(|b| b.name().to_string()).collect()
    }

    /// Advance the virtual clock to `t_s` (monotone); queued work
    /// drains by the elapsed interval on every backend.
    pub fn advance_to(&mut self, t_s: f64) {
        let dt = t_s - self.clock_s;
        if dt <= 0.0 {
            return;
        }
        for b in &mut self.backends {
            b.drain_queue_s(dt);
        }
        self.clock_s = t_s;
    }

    /// Route one request (`samples` samples of `profile` for logical
    /// `instance`) to any backend.
    pub fn submit(&mut self, instance: &str, profile: &ModelProfile, samples: usize) -> Routed {
        let all: Vec<usize> = (0..self.backends.len()).collect();
        self.submit_among(&all, instance, profile, samples)
    }

    /// Route one request within a candidate subset (topologies use
    /// this to pin a model class to a tier, e.g. MIR → local GPUs,
    /// Hermit → the remote pool).
    pub fn submit_among(
        &mut self,
        candidates: &[usize],
        instance: &str,
        profile: &ModelProfile,
        samples: usize,
    ) -> Routed {
        let idx = policy::select(
            self.policy,
            &self.backends,
            &mut self.rr_cursor,
            &mut self.affinity,
            candidates,
            instance,
            profile,
            samples,
        );
        let backend = &mut self.backends[idx];
        let wait_s = backend.queue_s();
        let link_overhead_s = backend.link_overhead_s(profile, samples);
        let latency_s = wait_s + backend.latency_s(profile, samples);
        let occupancy = backend.occupancy_s(profile, samples);
        backend.add_queue_s(occupancy);

        let stat = &mut self.stats[idx];
        stat.requests += 1;
        stat.samples += samples as u64;
        stat.busy_s += occupancy;
        self.last_completion_s = self.last_completion_s.max(self.clock_s + latency_s);

        Routed { backend: idx, wait_s, latency_s, link_overhead_s }
    }

    /// Total samples routed so far (conservation invariant: equals
    /// the total submitted).
    pub fn routed_samples(&self) -> u64 {
        self.stats.iter().map(|s| s.samples).sum()
    }

    /// Total requests routed so far.
    pub fn routed_requests(&self) -> u64 {
        self.stats.iter().map(|s| s.requests).sum()
    }

    /// Virtual time at which the last routed request completes.
    pub fn makespan_s(&self) -> f64 {
        self.last_completion_s.max(self.clock_s)
    }

    /// Per-backend accounting snapshot.
    pub fn report(&self) -> Vec<BackendReport> {
        self.backends
            .iter()
            .zip(&self.stats)
            .map(|(b, s)| BackendReport {
                name: b.name().to_string(),
                requests: s.requests,
                samples: s.samples,
                busy_s: s.busy_s,
                queue_s: b.queue_s(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{profiles, Api, Gpu};
    use crate::rdu::RduApi;

    fn gpu_fleet(n: usize) -> Vec<Box<dyn Backend>> {
        (0..n)
            .map(|i| {
                Box::new(GpuBackend::node_local(
                    format!("gpu/rank{i}"),
                    Gpu::a100(),
                    Api::TrtCudaGraphs,
                )) as Box<dyn Backend>
            })
            .collect()
    }

    fn mixed_pool() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
            Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::CppOptimized)),
        ]
    }

    #[test]
    fn round_robin_cycles_evenly() {
        let mut c = Cluster::new(gpu_fleet(3), Policy::RoundRobin);
        let p = profiles::hermit();
        for i in 0..9 {
            let r = c.submit("hermit/mat0", &p, 8);
            assert_eq!(r.backend, i % 3);
        }
        for rep in c.report() {
            assert_eq!(rep.requests, 3);
        }
    }

    #[test]
    fn conservation_of_samples_and_requests() {
        let mut c = Cluster::new(mixed_pool(), Policy::LeastOutstanding);
        let p = profiles::hermit();
        let mut total = 0u64;
        for i in 1..=40usize {
            let samples = 1 + (i * 7) % 93;
            c.submit(&format!("hermit/mat{}", i % 8), &p, samples);
            total += samples as u64;
        }
        assert_eq!(c.routed_samples(), total);
        assert_eq!(c.routed_requests(), 40);
        let by_backend: u64 = c.report().iter().map(|r| r.samples).sum();
        assert_eq!(by_backend, total);
    }

    #[test]
    fn affinity_is_sticky_per_instance() {
        let mut c = Cluster::new(gpu_fleet(4), Policy::ModelAffinity);
        let p = profiles::hermit();
        let first: Vec<usize> =
            (0..6).map(|m| c.submit(&format!("hermit/mat{m}"), &p, 16).backend).collect();
        // replay: every instance must revisit its backend
        for (m, &expect) in first.iter().enumerate() {
            let r = c.submit(&format!("hermit/mat{m}"), &p, 16);
            assert_eq!(r.backend, expect, "mat{m}");
        }
        // and the 6 instances spread over all 4 backends (least-loaded
        // first sighting)
        let distinct: std::collections::BTreeSet<usize> = first.iter().copied().collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn latency_aware_prefers_the_faster_backend_when_idle() {
        // heterogeneous pool: the 4-tile group executes faster than
        // the 2-tile group, so an idle cluster routes there
        let mut c = Cluster::new(mixed_pool(), Policy::LatencyAware);
        let p = profiles::hermit();
        let r = c.submit("hermit/mat0", &p, 256);
        assert_eq!(c.backend_names()[r.backend], "rdu/pool0");
        // ... until its queue makes the slower backend cheaper
        let mut saw_pool1 = false;
        for _ in 0..64 {
            let r = c.submit("hermit/mat0", &p, 256);
            if r.backend == 1 {
                saw_pool1 = true;
            }
        }
        assert!(saw_pool1, "queue pressure must spill to the slower backend");
    }

    #[test]
    fn least_outstanding_balances_heterogeneous_sizes() {
        let p = profiles::hermit();
        let sizes: Vec<usize> = (0..32).map(|i| 1 + (i * 37) % 200).collect();

        let mut rr = Cluster::new(mixed_pool(), Policy::RoundRobin);
        let mut lo = Cluster::new(mixed_pool(), Policy::LeastOutstanding);
        for &s in &sizes {
            rr.submit("hermit/mat0", &p, s);
            lo.submit("hermit/mat0", &p, s);
        }
        let max_q = |c: &Cluster| {
            c.report().iter().map(|r| r.queue_s).fold(0.0f64, f64::max)
        };
        assert!(max_q(&lo) <= max_q(&rr) + 1e-12, "{} vs {}", max_q(&lo), max_q(&rr));
    }

    #[test]
    fn waiting_behind_queue_raises_latency() {
        let mut c = Cluster::new(gpu_fleet(1), Policy::RoundRobin);
        let p = profiles::hermit();
        let first = c.submit("hermit/mat0", &p, 64);
        assert_eq!(first.wait_s, 0.0);
        let second = c.submit("hermit/mat0", &p, 64);
        assert!(second.wait_s > 0.0);
        assert!(second.latency_s > first.latency_s);
    }

    #[test]
    fn advance_drains_queues_and_clock_is_monotone() {
        let mut c = Cluster::new(gpu_fleet(2), Policy::RoundRobin);
        let p = profiles::hermit();
        for _ in 0..8 {
            c.submit("hermit/mat0", &p, 1024);
        }
        assert!(c.report().iter().any(|r| r.queue_s > 0.0));
        let makespan = c.makespan_s();
        c.advance_to(makespan + 1.0);
        assert!(c.report().iter().all(|r| r.queue_s == 0.0));
        // going backwards is a no-op
        c.advance_to(0.0);
        assert_eq!(c.clock_s(), makespan + 1.0);
    }

    #[test]
    fn submit_among_respects_the_candidate_subset() {
        let mut backends = gpu_fleet(2);
        backends.extend(mixed_pool());
        let mut c = Cluster::new(backends, Policy::LatencyAware);
        let p = profiles::hermit();
        for i in 0..10 {
            let r = c.submit_among(&[2, 3], &format!("hermit/mat{i}"), &p, 64);
            assert!(r.backend == 2 || r.backend == 3);
        }
        let rep = c.report();
        assert_eq!(rep[0].requests + rep[1].requests, 0);
        assert_eq!(rep[2].requests + rep[3].requests, 10);
    }

    #[test]
    fn remote_backends_report_link_overhead() {
        let mut c = Cluster::new(mixed_pool(), Policy::RoundRobin);
        let p = profiles::hermit();
        let r = c.submit("hermit/mat0", &p, 1024);
        assert!(r.link_overhead_s > 0.0);
        let mut local = Cluster::new(gpu_fleet(1), Policy::RoundRobin);
        let r = local.submit("hermit/mat0", &p, 1024);
        assert_eq!(r.link_overhead_s, 0.0);
    }
}
