//! Minimal in-tree implementation of the `anyhow` API surface this
//! workspace uses.  The offline build image cannot fetch crates, so
//! the error-context ergonomics (`anyhow!`, `bail!`, `Context`,
//! `Result<T>`, `{err:#}` chain display) are provided here.
//!
//! Differences from real anyhow: the error holds a rendered message
//! chain (`Vec<String>`) instead of a boxed source chain, so
//! downcasting is not supported — nothing in this workspace downcasts.

use std::fmt;

/// A rendered error: the outermost message first, then each wrapped
/// cause in order.  `{e}` prints only the outermost message; `{e:#}`
/// prints the full chain separated by `: ` (anyhow's convention).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// All messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a cause list.
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow: Error deliberately does NOT implement
// std::error::Error, which frees up the blanket From below.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` (subset of anyhow's
/// `Context` trait: any `Display` error type is accepted).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        // `{:#}` keeps a wrapped anyhow::Error's own chain intact.
        self.map_err(|e| Error::msg(format!("{e:#}")).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        return Err($crate::anyhow!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn macro_forms() {
        let name = "hermit";
        let e = anyhow!("model {name:?} missing");
        assert_eq!(format!("{e}"), "model \"hermit\" missing");
        let s = String::from("plain message");
        let e2 = anyhow!(s);
        assert_eq!(format!("{e2}"), "plain message");
    }

    #[test]
    fn bail_returns_err() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {}", flag);
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert!(format!("{:#}", f(true).unwrap_err()).contains("true"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = "abc".parse::<u32>()?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
