//! Minimal work-stealing thread pool for embarrassingly parallel,
//! index-keyed map operations.  Std-only (no network deps, same
//! posture as the vendored `anyhow`): each worker owns a deque seeded
//! round-robin, pops its own front, and steals from the back of other
//! workers' deques when its own runs dry.  `map` never spawns new
//! work mid-flight, so workers simply exit once every deque is empty.
//!
//! Determinism contract: results are returned keyed by input index,
//! in input order, regardless of which worker ran which item or in
//! what order items completed.  With `threads <= 1` (or a single
//! item) the map runs inline on the caller's thread — the exact
//! legacy sequential path, no threads spawned at all.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A fixed-width pool configuration.  Cheap to construct; threads are
/// spawned per `map` call via `std::thread::scope` so the pool holds
/// no OS resources between calls.
#[derive(Debug, Clone, Copy)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads == 0` means "all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads }
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, returning results in input order.
    /// `f` receives `(index, item)` so callers can key side tables by
    /// position.  Panics in `f` propagate to the caller.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            // Exact legacy path: inline, sequential, no threads.
            return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
        }
        let workers = self.threads.min(n);
        // Seed the per-worker deques round-robin so early indices are
        // spread across workers.
        let mut queues: Vec<Mutex<VecDeque<(usize, T)>>> =
            (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
        for (i, item) in items.into_iter().enumerate() {
            queues[i % workers].get_mut().unwrap().push_back((i, item));
        }
        let queues = &queues;
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
        let slots = &slots;
        let f = &f;
        std::thread::scope(|scope| {
            for w in 0..workers {
                scope.spawn(move || loop {
                    // Own queue first (front), then steal from the
                    // back of the others.  All queues empty => done:
                    // map spawns no new work.
                    let task = queues[w].lock().unwrap().pop_front().or_else(|| {
                        (1..workers)
                            .map(|d| (w + d) % workers)
                            .find_map(|v| queues[v].lock().unwrap().pop_back())
                    });
                    match task {
                        Some((i, item)) => {
                            let r = f(i, item);
                            slots.lock().unwrap()[i] = Some(r);
                        }
                        None => break,
                    }
                });
            }
        });
        let collected: Vec<R> = slots
            .lock()
            .unwrap()
            .iter_mut()
            .map(|s| s.take().expect("worker completed every seeded item"))
            .collect();
        collected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_means_available_parallelism() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
    }

    #[test]
    fn map_preserves_input_order() {
        for threads in [1, 2, 8] {
            let pool = Pool::new(threads);
            let out = pool.map((0..100).collect(), |i, x: usize| {
                assert_eq!(i, x);
                x * x
            });
            assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
        }
    }

    #[test]
    fn uneven_work_is_stolen() {
        // Front-load the heavy items so a single worker would choke;
        // the result must still come back in index order.
        let pool = Pool::new(4);
        let out = pool.map((0..32).collect(), |_, x: u64| {
            let spins = if x < 4 { 200_000 } else { 10 };
            let mut acc = x;
            for _ in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            // Return the index-determined part only.
            let _ = acc;
            x + 1
        });
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    #[test]
    fn single_item_runs_inline() {
        let pool = Pool::new(8);
        let out = pool.map(vec![7usize], |i, x| (i, x));
        assert_eq!(out, vec![(0, 7)]);
    }

    #[test]
    fn empty_input() {
        let pool = Pool::new(4);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |_, x| x);
        assert!(out.is_empty());
    }
}
