//! API-compatible **stub** of the `xla-rs` PJRT bridge.
//!
//! The offline build image has no PJRT plugin and no network access,
//! so this crate provides exactly the type/method surface
//! `cogsim_disagg::runtime::engine` compiles against, with every
//! device-touching operation returning a descriptive [`Error`] at
//! runtime.  Swapping in the real `xla` crate (same names, same
//! signatures) re-enables execution of the AOT artifacts on hardware;
//! nothing in the workspace needs to change.
//!
//! The serving stack does not depend on this path working: the
//! runtime's simulated engine (`Engine::sim_reference`) provides a
//! deterministic pure-Rust executor for tests, examples and the
//! cluster campaign harness.

use std::fmt;
use std::path::Path;

/// Stub error: carries the reason the offline path cannot execute.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unsupported(what: &str) -> Error {
        Error {
            message: format!(
                "{what} requires the real xla-rs PJRT bridge, which is unavailable \
                 in this offline build (vendor/xla is an API stub); use the \
                 simulated engine instead"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Types loadable from raw npz/npy bytes (trait shape mirrors xla-rs).
pub trait FromRawBytes: Sized {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        _context: &(),
        names: &[&str],
    ) -> Result<Vec<Self>>;
}

/// A host-side literal tensor.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unsupported("Literal::to_vec"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unsupported("Literal::to_tuple1"))
    }
}

impl FromRawBytes for Literal {
    fn read_npz_by_name(
        path: impl AsRef<Path>,
        _context: &(),
        _names: &[&str],
    ) -> Result<Vec<Literal>> {
        Err(Error::unsupported(&format!(
            "reading {:?} as npz literals",
            path.as_ref()
        )))
    }
}

/// Parsed HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unsupported(&format!(
            "parsing HLO text {:?}",
            path.as_ref()
        )))
    }
}

/// An XLA computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unsupported("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unsupported("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client (CPU plugin in the paper reproduction).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unsupported("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unsupported("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unsupported("PjRtClient::buffer_from_host_literal"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unsupported("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("simulated engine"), "{err}");
        let err =
            Literal::read_npz_by_name("/tmp/nope.npz", &(), &["x"]).unwrap_err();
        assert!(err.to_string().contains("offline build"), "{err}");
    }
}
