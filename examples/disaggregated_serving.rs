//! END-TO-END DRIVER: the full disaggregated CogSim system on one
//! real workload — all three layers composing.
//!
//! What runs:
//! 1. the **server** (Layer 3): PJRT engine with 8 per-material Hermit
//!    instances + MIR, dynamic batcher, threaded TCP front-end — the
//!    DataScale-node role;
//! 2. N **MPI-rank clients** over real TCP replaying a Hydra
//!    in-the-loop trace (2–3 inferences/zone across 8 materials) in
//!    latency mode, then a throughput phase with pipelined submission
//!    (mini-batch n+1 in flight before n returns, §V-A);
//! 3. reports per-rank latency (mean/p95/p99), end-to-end throughput,
//!    batching effectiveness, and the local-vs-remote overhead — the
//!    paper's Figs. 15/16 measured on *this* testbed.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example disaggregated_serving -- [ranks] [timesteps] [zones]
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use cogsim_disagg::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::metrics::LatencyRecorder;
use cogsim_disagg::net::{Client, Server};
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::util::stats;
use cogsim_disagg::workload::HydraWorkload;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let ranks: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let timesteps: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let zones: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(400);

    // ---------------- server side (the "DataScale node") ----------------
    println!("[server] loading artifacts + compiling executables ...");
    let engine = Engine::load("artifacts", Some(&["hermit", "mir"]))?;
    let mut registry = Registry::new();
    registry.register_materials("hermit", 8);
    registry.register("mir", "mir");
    let coordinator = Arc::new(Coordinator::start(
        engine,
        registry,
        CoordinatorConfig {
            batcher: BatcherConfig {
                target_batch: 256,
                max_wait: std::time::Duration::from_micros(300),
                deferred_max_wait: std::time::Duration::from_millis(50),
                max_batch: 1024,
            },
            workers: 1,
        },
    )?);
    let server = Server::serve(Arc::clone(&coordinator), "127.0.0.1:0")?;
    let addr = server.addr();
    println!("[server] serving 9 instances on {addr}");

    // --------------- phase 1: in-the-loop latency (per rank) ------------
    let workload = HydraWorkload {
        ranks,
        zones_per_rank: zones,
        materials: 8,
        inferences_per_zone: (2, 3),
        seed: 42,
    };
    println!(
        "\n[phase 1] {ranks} ranks x {timesteps} timesteps x {zones} zones (latency mode)"
    );
    let t_phase1 = Instant::now();
    let handles: Vec<_> = (0..ranks)
        .map(|rank| {
            let workload = workload.clone();
            std::thread::spawn(move || -> Result<(LatencyRecorder, usize)> {
                let client = Client::connect(addr)?;
                let mut rng = Rng::new(1000 + rank as u64);
                let mut latency = LatencyRecorder::new();
                let mut samples = 0usize;
                for t in 0..timesteps {
                    for req in workload
                        .timestep(t)
                        .into_iter()
                        .filter(|r| r.rank == rank)
                    {
                        let x = rng.normal_vec(req.samples * 42);
                        let t0 = Instant::now();
                        let rows = client.infer(&req.model, req.samples, &x)?;
                        latency.record(t0.elapsed());
                        assert_eq!(rows.len(), req.samples * 30);
                        samples += req.samples;
                    }
                }
                Ok((latency, samples))
            })
        })
        .collect();

    let mut total_samples = 0usize;
    let mut rank_means = Vec::new();
    for (rank, h) in handles.into_iter().enumerate() {
        let (latency, samples) = h.join().expect("rank thread")?;
        total_samples += samples;
        rank_means.push(latency.mean_s());
        println!(
            "  rank {rank}: {samples} samples, request latency mean {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
            latency.mean_s() * 1e3,
            latency.p95_s() * 1e3,
            latency.p99_s() * 1e3,
        );
    }
    let wall1 = t_phase1.elapsed();
    println!(
        "  phase-1 aggregate: {total_samples} samples in {wall1:?} ({:.0} samples/s), \
         mean-of-rank-means {:.3} ms",
        total_samples as f64 / wall1.as_secs_f64(),
        stats::mean(&rank_means) * 1e3
    );

    // --------------- phase 2: pipelined throughput ----------------------
    println!("\n[phase 2] pipelined throughput (mini-batch 256, depth 4, 1 rank/conn)");
    let t_phase2 = Instant::now();
    let per_rank: Vec<_> = (0..ranks)
        .map(|rank| {
            std::thread::spawn(move || -> Result<usize> {
                let client = Client::connect(addr)?;
                let mut rng = Rng::new(2000 + rank as u64);
                let batch = 256usize;
                let n_batches = 24usize;
                let payload = rng.normal_vec(batch * 42);
                let model = format!("hermit/mat{}", rank % 8);

                let mut inflight = std::collections::VecDeque::new();
                for _ in 0..n_batches {
                    while inflight.len() >= 4 {
                        let rx = inflight.pop_front().unwrap();
                        client.recv(rx)?;
                    }
                    inflight.push_back(client.submit(&model, batch, &payload)?);
                }
                for rx in inflight {
                    client.recv(rx)?;
                }
                Ok(batch * n_batches)
            })
        })
        .collect();
    let phase2_samples: usize = per_rank
        .into_iter()
        .map(|h| h.join().expect("rank thread").expect("phase 2"))
        .sum();
    let wall2 = t_phase2.elapsed();
    println!(
        "  {} samples in {:?} -> {:.0} samples/s aggregate",
        phase2_samples,
        wall2,
        phase2_samples as f64 / wall2.as_secs_f64()
    );

    // --------------- local vs remote overhead (Fig. 15 analogue) --------
    println!("\n[phase 3] local vs remote single-request overhead (batch 4)");
    let client = Client::connect(addr)?;
    let mut rng = Rng::new(3000);
    let x = rng.normal_vec(4 * 42);
    let reps = 50;
    // warm-up
    for _ in 0..10 {
        let _ = client.infer("hermit/mat0", 4, &x)?;
        let _ = coordinator.infer("hermit/mat0", x.clone())?;
    }
    let mut remote = LatencyRecorder::new();
    let mut local = LatencyRecorder::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let _ = client.infer("hermit/mat0", 4, &x)?;
        remote.record(t0.elapsed());
        let t1 = Instant::now();
        let _ = coordinator.infer("hermit/mat0", x.clone())?;
        local.record(t1.elapsed());
    }
    println!(
        "  local (in-process)  mean {:.3} ms   remote (TCP) mean {:.3} ms   overhead {:.3} ms",
        local.mean_s() * 1e3,
        remote.mean_s() * 1e3,
        (remote.mean_s() - local.mean_s()) * 1e3
    );

    // --------------- server-side accounting ----------------------------
    let stats = &coordinator.stats;
    use std::sync::atomic::Ordering::Relaxed;
    println!("\n--- server stats ---");
    println!("requests        {}", stats.requests.load(Relaxed));
    println!("engine batches  {} ({:.1} samples/batch)", stats.batches.load(Relaxed), stats.samples_per_batch());
    println!("errors          {}", stats.errors.load(Relaxed));
    println!("connections     {}", server.connections_accepted());

    server.shutdown();
    Ok(())
}
