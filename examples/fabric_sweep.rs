//! Fabric sweep: the contention-aware network layer end to end.
//!
//! Part 1 drives the flow-level [`FabricEngine`] directly — eight
//! hosts bursting into a two-accelerator pool — and prints the
//! max-min fair shares as flows join and leave.  Part 2 runs the
//! coupled CogSim model over the same fabric across oversubscription
//! factors and shows where the shared pool's time-to-solution loses
//! to per-rank local GPUs.
//!
//! ```bash
//! cargo run --release --example fabric_sweep
//! ```

use cogsim_disagg::cluster::Policy;
use cogsim_disagg::fabric::{FabricEngine, FabricSpec, Topology};
use cogsim_disagg::harness::{
    run_cog_scenario, CogCampaignConfig, Topology as CampaignTopology,
};

fn main() {
    // ---- part 1: fair share on the wire ----------------------------
    println!("8 hosts -> 2 pooled accels, 4:1 oversubscribed, 1 MB each:\n");
    let topo = Topology::pooled(8, 2, 4.0);
    let mut eng = FabricEngine::new(topo);
    let mut flows = Vec::new();
    for h in 0..8 {
        let path = eng.topology().request_path(h, h % 2);
        flows.push(eng.start(0.0, path, 1e6));
    }
    println!(
        "  burst: {} active flows, per-flow share {:.0} MB/s",
        eng.active(),
        eng.rate_of(flows[0]).unwrap() / 1e6
    );
    while let Some(t) = eng.next_completion_s() {
        let done = eng.take_completed(t);
        let share = flows
            .iter()
            .find_map(|&f| eng.rate_of(f))
            .map(|r| format!("{:.0} MB/s", r / 1e6))
            .unwrap_or_else(|| "idle".to_string());
        println!(
            "  t={:>7.1} us: {} finished, {} left, share now {}",
            t * 1e6,
            done.len(),
            eng.active(),
            share
        );
    }

    // ---- part 2: the coupled crossover -----------------------------
    println!("\nCogSim pooled-vs-local TTS across the oversubscription knob:\n");
    let cfg = CogCampaignConfig::default();
    for ranks in [4usize, 32] {
        let local = run_cog_scenario(
            CampaignTopology::Local,
            Policy::LatencyAware,
            ranks,
            8,
            0.0,
            0.0,
            1.0,
            &cfg,
        );
        println!(
            "  {ranks} ranks, local GPUs: {:>8.2} ms",
            local.summary.time_to_solution_s * 1e3
        );
        for oversub in [1.0, 2.0, 4.0, 8.0] {
            let pooled = run_cog_scenario(
                CampaignTopology::Pooled,
                Policy::LatencyAware,
                ranks,
                8,
                0.0,
                0.0,
                oversub,
                &cfg,
            );
            let s = &pooled.summary;
            println!(
                "  {ranks} ranks, pool {oversub}:1:   {:>8.2} ms \
                 (network {:.2} ms of which contention {:.2} ms){}",
                s.time_to_solution_s * 1e3,
                s.total_network_s * 1e3,
                s.total_contention_s * 1e3,
                if s.time_to_solution_s > local.summary.time_to_solution_s {
                    "  <- pooled loses"
                } else {
                    ""
                }
            );
        }
    }

    // the spec plumbing the campaign uses under the hood
    let spec = FabricSpec {
        topology: Topology::hybrid(4, 2, 4.0),
        accel_of_backend: vec![0, 1, 2, 3, 4, 5],
    };
    println!(
        "\nhybrid spec: {} hosts, {} accels ({} pooled), rank 5 -> host {}",
        spec.topology.hosts(),
        spec.topology.accels(),
        (0..spec.topology.accels()).filter(|&a| spec.topology.is_pooled(a)).count(),
        spec.host_of_rank(5)
    );
}
