//! MIR material-interface reconstruction pipeline (paper §IV-B).
//!
//! Generates synthetic volume-fraction images with a known linear
//! material interface (the structure MIR sees from the hydro code),
//! reconstructs them through the AOT-compiled MIR autoencoder, and
//! reports the two things the paper cares about:
//!
//! * reconstruction quality proxies — volume conservation (PLIC
//!   conserves volume exactly; MIR should come close) and continuity;
//! * throughput against the 100K samples/s/rank target.
//!
//! ```bash
//! cargo run --release --example mir_pipeline -- [timesteps]
//! ```

use anyhow::Result;
use cogsim_disagg::metrics::ThroughputCounter;
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::workload::MirWorkload;

const IMG: usize = 48;

/// A smoothed half-plane interface image (matches
/// `python/compile/models/mir.py::sample_input`).
fn interface_image(rng: &mut Rng) -> Vec<f32> {
    let theta = rng.uniform(0.0, std::f64::consts::TAU);
    let offset = rng.uniform(0.3, 0.7);
    let sharpness = rng.uniform(8.0, 24.0);
    let (c, s) = (theta.cos(), theta.sin());
    (0..IMG * IMG)
        .map(|i| {
            let (y, x) = ((i / IMG) as f64 / IMG as f64, (i % IMG) as f64 / IMG as f64);
            let d = c * x + s * y - offset;
            (1.0 / (1.0 + (-d * sharpness).exp())) as f32
        })
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let timesteps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);

    let engine = Engine::load("artifacts", Some(&["mir"]))?;
    let workload = MirWorkload { ranks: 1, base_zones: 256, variation: 0.4, seed: 3 };
    let mut rng = Rng::new(11);

    let mut volume_errors = Vec::new();
    let mut counter = ThroughputCounter::new();

    for t in 0..timesteps {
        for req in workload.timestep(t) {
            // zone images for this timestep's mixed zones
            let n = req.samples.min(512); // keep the example brisk on CPU
            let mut batch = Vec::with_capacity(n * IMG * IMG);
            for _ in 0..n {
                batch.extend(interface_image(&mut rng));
            }
            let (recon, timing) = engine.execute_padded("mir", &batch)?;
            counter.add(n);

            // volume conservation per zone: mean volume fraction of
            // the reconstruction vs the input (PLIC is exact at 0).
            for z in 0..n {
                let zone_in = &batch[z * IMG * IMG..(z + 1) * IMG * IMG];
                let zone_out = &recon[z * IMG * IMG..(z + 1) * IMG * IMG];
                let vin: f32 = zone_in.iter().sum::<f32>() / (IMG * IMG) as f32;
                let vout: f32 = zone_out.iter().sum::<f32>() / (IMG * IMG) as f32;
                volume_errors.push((vin - vout).abs() as f64);
            }
            println!(
                "timestep {t} rank {}: {} zones reconstructed (exec {:?})",
                req.rank, n, timing.execute
            );
        }
    }

    let mean_vol_err =
        volume_errors.iter().sum::<f64>() / volume_errors.len().max(1) as f64;
    let throughput = counter.per_second();
    println!("\n--- summary ---");
    println!("zones reconstructed      {}", counter.samples());
    println!("mean |volume error|      {mean_vol_err:.4}");
    println!("throughput               {throughput:.0} samples/s (CPU testbed)");
    println!(
        "paper target             {:.0} samples/s/rank (A100/RDU scale, Fig. 20)",
        MirWorkload::TARGET_SAMPLES_PER_SEC_PER_RANK
    );
    // With `make train` the served weights are trained on the same
    // interface distribution (BCE 0.90 -> 0.17 over 300 steps) and the
    // volume error drops to ~0.05; with random init this is purely a
    // plumbing check.  CPU throughput is interpret-mode Pallas — the
    // paper-scale numbers come from the calibrated device models.
    println!("\n(run `make train` to serve trained weights; see EXPERIMENTS.md §Training)");
    Ok(())
}
