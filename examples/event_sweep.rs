//! Event sweep: the discrete-event simulator end to end.
//!
//! Replays bursty 64-rank CogSim arrivals against the disaggregated
//! RDU pool with and without a router-level dynamic-batching window —
//! the queueing experiment the analytic cluster cannot run — then
//! sweeps the full event campaign (topology × policy × rank count ×
//! arrival process × window) and writes its deterministic JSON.
//!
//! ```bash
//! cargo run --release --example event_sweep
//! ```

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{ArrivalProcess, Batching, EventSim, EventSimConfig};
use cogsim_disagg::harness::{run_event_campaign, EventCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn main() {
    // ---- part 1: one bursty 64-rank scenario, batching on vs off ----
    println!("bursty 64-rank arrivals on the shared RDU pool:\n");
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "batching", "requests", "batches", "p50 (us)", "p99 (us)", "p99.9 (us)"
    );
    for (label, batching) in [
        ("off", Batching::Off),
        ("window 200us", Batching::Window { window_s: 200e-6, max_batch: 256 }),
    ] {
        let cfg = EventSimConfig {
            ranks: 64,
            arrival: ArrivalProcess::Synchronized { period_s: 0.02, jitter_s: 0.0 },
            batching,
            horizon_s: 0.1,
            ..Default::default()
        };
        let mut sim = EventSim::new(pool(), Policy::LatencyAware, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        println!(
            "{:<22} {:>10} {:>10} {:>10.1} {:>10.1} {:>10.1}",
            label,
            s.requests,
            s.batches,
            s.latency.p50_s * 1e6,
            s.latency.p99_s * 1e6,
            s.latency.p999_s * 1e6
        );
    }

    // ---- part 2: the full event campaign ----
    let cfg = EventCampaignConfig { horizon_s: 0.1, ..Default::default() };
    let result = run_event_campaign(&cfg);
    println!();
    for table in result.tables() {
        println!("{}", table.render());
    }
    let path = "results/event_sweep.json";
    std::fs::create_dir_all("results").expect("mkdir results");
    std::fs::write(path, json::write(&result.to_json())).expect("write json");
    println!("wrote {path}");
}
