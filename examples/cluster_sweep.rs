//! Cluster sweep: the multi-backend layer end to end.
//!
//! Composes heterogeneous backends (per-rank A100s, a disaggregated
//! RDU pool) into a `Cluster`, routes a Hydra timestep through each
//! routing policy by hand, then runs the full topology × policy
//! campaign and writes the JSON summary — the many-accelerator
//! extension of the paper's single-device evaluation.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use cogsim_disagg::cluster::{Backend, Cluster, GpuBackend, Policy, RduBackend};
use cogsim_disagg::devices::{profiles, Api, Gpu};
use cogsim_disagg::harness::{run_campaign, CampaignConfig, Topology};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;
use cogsim_disagg::util::stats;
use cogsim_disagg::workload::HydraWorkload;

fn fleet() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(GpuBackend::node_local("gpu/rank0", Gpu::a100(), Api::TrtCudaGraphs)),
        Box::new(GpuBackend::node_local("gpu/rank1", Gpu::a100(), Api::TrtCudaGraphs)),
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn main() {
    // ---- part 1: one timestep through each policy, by hand ----
    let workload = HydraWorkload { ranks: 2, zones_per_rank: 400, ..Default::default() };
    let profile = profiles::hermit();
    println!("routing one Hydra timestep ({} requests) across 4 backends:\n",
        workload.timestep(0).len());
    println!(
        "{:<20} {:>12} {:>12} {:>12}",
        "policy", "p50 (us)", "p99 (us)", "max wait (us)"
    );
    for policy in Policy::ALL {
        let mut cluster = Cluster::new(fleet(), policy);
        let mut latencies = Vec::new();
        let mut max_wait: f64 = 0.0;
        for req in workload.timestep(0) {
            let routed = cluster.submit(&req.model, &profile, req.samples);
            latencies.push(routed.latency_s);
            max_wait = max_wait.max(routed.wait_s);
        }
        println!(
            "{:<20} {:>12.1} {:>12.1} {:>12.1}",
            policy.label(),
            stats::percentile(&latencies, 50.0) * 1e6,
            stats::percentile(&latencies, 99.0) * 1e6,
            max_wait * 1e6
        );
    }

    // ---- part 2: the full campaign ----
    println!("\nrunning the full topology x policy campaign ...\n");
    let result = run_campaign(&CampaignConfig::default());
    for table in result.tables() {
        println!("{}", table.render());
    }
    let la = result.scenario(Topology::Hybrid, Policy::LatencyAware);
    let rr = result.scenario(Topology::Hybrid, Policy::RoundRobin);
    println!(
        "hybrid Hydra p99: latency-aware {:.1} us vs round-robin {:.1} us",
        la.hydra.p99_s * 1e6,
        rr.hydra.p99_s * 1e6
    );

    std::fs::create_dir_all("results").ok();
    let json_text = json::write(&result.to_json());
    std::fs::write("results/cluster_sweep.json", &json_text).expect("write results");
    println!("wrote results/cluster_sweep.json ({} bytes)", json_text.len());
}
