//! CogSim sweep: the coupled timestep/inference application model
//! end to end.
//!
//! Part 1 runs one coupled scenario directly — 16 ranks stalling each
//! bulk-synchronous timestep on a burst of per-material requests
//! against the shared RDU pool — and prints the per-timestep
//! critical-path breakdown under free vs expensive model swaps.
//! Part 2 sweeps the full cogsim campaign (topology × policy × swap ×
//! overlap) and writes its deterministic JSON.
//!
//! ```bash
//! cargo run --release --example cogsim_sweep
//! ```

use cogsim_disagg::cluster::{Backend, Policy, RduBackend};
use cogsim_disagg::eventsim::{CogSim, CogSimConfig};
use cogsim_disagg::harness::{run_cog_campaign, CogCampaignConfig};
use cogsim_disagg::rdu::RduApi;
use cogsim_disagg::util::json;

fn pool() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(RduBackend::disaggregated("rdu/pool0", 4, RduApi::CppOptimized)),
        Box::new(RduBackend::disaggregated("rdu/pool1", 2, RduApi::Python)),
    ]
}

fn main() {
    // ---- part 1: one coupled run, swap cost on vs off --------------
    println!("16 ranks x 8 timesteps on the shared RDU pool (model-affinity):\n");
    for (label, swap_s) in [("swaps free", 0.0), ("swap 2 ms", 2e-3)] {
        let cfg = CogSimConfig {
            ranks: 16,
            timesteps: 8,
            swap_s,
            ..Default::default()
        };
        let mut sim = CogSim::new(pool(), Policy::ModelAffinity, cfg);
        sim.run_to_completion();
        let s = sim.summary();
        println!(
            "{label:<12} TTS {:>8.2} ms  (compute {:.2} / queue {:.2} / swap {:.2} / \
             net {:.2} / service {:.2} ms, {} swaps)",
            s.time_to_solution_s * 1e3,
            s.total_compute_s * 1e3,
            s.total_queue_s * 1e3,
            s.total_swap_s * 1e3,
            s.total_network_s * 1e3,
            s.total_service_s * 1e3,
            s.swaps
        );
        println!("             per-step critical path (ms):");
        for st in s.steps.iter().take(3) {
            println!(
                "               step {}: dur {:.3} = compute {:.3} + queue {:.3} + swap {:.3} \
                 + net {:.3} + service {:.3}  (straggler rank {}, spread {:.3})",
                st.step,
                st.duration_s() * 1e3,
                st.compute_s * 1e3,
                st.queue_s * 1e3,
                st.swap_s * 1e3,
                st.network_s * 1e3,
                st.service_s * 1e3,
                st.straggler,
                st.spread_s * 1e3
            );
        }
    }

    // ---- part 2: the full cogsim campaign --------------------------
    let cfg = CogCampaignConfig::default();
    let result = run_cog_campaign(&cfg);
    println!();
    for table in result.tables() {
        println!("{}", table.render());
    }
    let path = "results/cogsim_sweep.json";
    std::fs::create_dir_all("results").expect("mkdir results");
    std::fs::write(path, json::write(&result.to_json())).expect("write json");
    println!("wrote {path}");
}
