//! Quickstart: load the AOT artifacts, run one Hermit and one MIR
//! inference through the PJRT engine, print the timing breakdown.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Load the engine: compiles every (model, batch) artifact once
    //    and uploads the weights to device buffers.
    let engine = Engine::load("artifacts", None)?;
    println!("loaded models: {:?}", engine.model_names());

    // 2. Hermit: a 42-value NLTE state vector -> 30 opacity bins.
    let mut rng = Rng::new(0);
    let x = rng.normal_vec(42);
    let (opacities, timing) = engine.execute("hermit", 1, &x)?;
    println!("\nhermit batch=1:");
    println!("  output ({} bins): {:?} ...", opacities.len(), &opacities[..4]);
    println!(
        "  upload {:?}  execute {:?}  fetch {:?}",
        timing.upload, timing.execute, timing.fetch
    );

    // 3. MIR: a 48x48 volume-fraction image -> reconstructed interface.
    let image: Vec<f32> = (0..48 * 48)
        .map(|i| {
            let (y, x) = (i / 48, i % 48);
            if y + x > 48 { 1.0 } else { 0.0 } // diagonal material interface
        })
        .collect();
    let (recon, timing) = engine.execute("mir", 1, &image)?;
    let mean: f32 = recon.iter().sum::<f32>() / recon.len() as f32;
    println!("\nmir batch=1:");
    println!("  reconstruction mean volume fraction: {mean:.3}");
    println!(
        "  upload {:?}  execute {:?}  fetch {:?}",
        timing.upload, timing.execute, timing.fetch
    );

    // 4. Batched execution pads to the compiled ladder automatically.
    let xs = rng.normal_vec(5 * 42);
    let (out, _) = engine.execute_padded("hermit", &xs)?;
    println!("\nhermit batch=5 (padded to ladder): {} rows", out.len() / 30);
    println!(
        "  padding waste at n=5: {:.0}%",
        engine.padding_waste("hermit", 5)? * 100.0
    );
    Ok(())
}
