//! In-the-loop Hermit inference, Hydra-style (paper §IV-A).
//!
//! Simulates the paper's workload: several MPI ranks, each owning
//! zones spread over 5–10 materials, issuing 2–3 inference requests
//! per zone per timestep against per-material Hermit instances.  The
//! coordinator batches per material; we report per-timestep latency,
//! batching effectiveness, and whether inference would bottleneck the
//! simulation loop.
//!
//! ```bash
//! cargo run --release --example hydra_inference -- [timesteps] [zones_per_rank]
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use cogsim_disagg::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Registry};
use cogsim_disagg::metrics::LatencyRecorder;
use cogsim_disagg::runtime::Engine;
use cogsim_disagg::util::rng::Rng;
use cogsim_disagg::workload::HydraWorkload;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let timesteps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(5);
    let zones: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(500);

    let workload = HydraWorkload {
        ranks: 4,
        zones_per_rank: zones,
        materials: 8,
        inferences_per_zone: (2, 3),
        seed: 42,
    };
    println!(
        "hydra workload: {} ranks x {} zones, {} materials, ~{} inferences/timestep",
        workload.ranks,
        workload.zones_per_rank,
        workload.materials,
        workload.expected_inferences_per_timestep()
    );

    let engine = Engine::load("artifacts", Some(&["hermit"]))?;
    let mut registry = Registry::new();
    registry.register_materials("hermit", workload.materials);
    let coordinator = Arc::new(Coordinator::start(
        engine,
        registry,
        CoordinatorConfig {
            batcher: BatcherConfig {
                target_batch: 256,
                max_wait: std::time::Duration::from_micros(300),
                deferred_max_wait: std::time::Duration::from_millis(50),
                max_batch: 1024,
            },
            workers: 1,
        },
    )?);

    let mut rng = Rng::new(7);
    let mut request_latency = LatencyRecorder::new();

    for t in 0..timesteps {
        let t_start = Instant::now();
        let requests = workload.timestep(t);
        let mut total_samples = 0usize;

        // Every (rank, material) issues its request concurrently —
        // this is what the batcher sees from real MPI ranks.
        let pending: Vec<_> = requests
            .iter()
            .map(|req| {
                total_samples += req.samples;
                let x = rng.normal_vec(req.samples * 42);
                let submitted = Instant::now();
                let rx = coordinator.submit(&req.model, x).unwrap();
                (req, submitted, rx)
            })
            .collect();

        for (req, submitted, rx) in pending {
            let rows = rx.recv().expect("coordinator alive").expect("inference ok");
            assert_eq!(rows.len(), req.samples * 30);
            request_latency.record(submitted.elapsed());
        }

        let wall = t_start.elapsed();
        println!(
            "timestep {t}: {} requests, {total_samples} samples in {:?} ({:.0} samples/s)",
            requests.len(),
            wall,
            total_samples as f64 / wall.as_secs_f64()
        );
    }

    let stats = &coordinator.stats;
    let requests = stats.requests.load(std::sync::atomic::Ordering::Relaxed);
    let batches = stats.batches.load(std::sync::atomic::Ordering::Relaxed);
    let padded = stats.padded_samples.load(std::sync::atomic::Ordering::Relaxed);
    let samples = stats.samples.load(std::sync::atomic::Ordering::Relaxed);
    println!("\n--- summary ---");
    println!("requests               {requests}");
    println!("engine batches         {batches} ({:.1} samples/batch)", stats.samples_per_batch());
    println!("padding overhead       {:.1}%", 100.0 * padded as f64 / samples as f64);
    println!("request latency mean   {:.3} ms", request_latency.mean_s() * 1e3);
    println!("request latency p95    {:.3} ms", request_latency.p95_s() * 1e3);
    println!("request latency p99    {:.3} ms", request_latency.p99_s() * 1e3);
    Ok(())
}
