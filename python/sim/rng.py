"""util::rng transliteration: SplitMix64-seeded xoshiro256**."""

import math

from rustfloat import MASK64

_INV_2_53 = 1.0 / float(1 << 53)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    __slots__ = ("s",)

    def __init__(self, seed: int):
        s = []
        sm = seed & MASK64
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self) -> float:
        return float(self.next_u64() >> 11) * _INV_2_53

    def below(self, n: int) -> int:
        assert n > 0
        return self.next_u64() % n

    def range(self, lo: int, hi: int) -> int:
        assert lo <= hi
        return lo + self.below(hi - lo + 1)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.f64()

    def normal(self) -> float:
        u1 = max(self.f64(), 1e-300)
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def exponential(self, rate: float) -> float:
        assert rate > 0.0
        return -math.log(max(self.f64(), 1e-300)) / rate
