"""simcore transliteration: the engine-agnostic request pipeline.

Mirrors rust/src/simcore/ — the shared request lifecycle both event
engines drive: policy routing, the router-level batching stage, the
residency/LRU swap stage, legacy fixed-charge dispatch, and the
multi-phase fabric path (payload flow in, weights-ready gate, per-
device busy clock, result flow out).

The engines (eventsim.EventSim, cogsim.CogSim) keep only workload
logic (arrival processes vs. timestep barriers) plus their record
stores; every dispatch/batch/fabric/service decision lives here once.

Effects protocol: pipeline methods never touch the engine's event
queue or records directly.  They accumulate, in exact legacy push
order,

* ``scheduled``  — (t_s, class, pipe_event) to insert into the event
  queue (the engine wraps them; insertion order defines heap seq
  numbers, so order is part of the byte-stability contract);
* ``dispatched`` — one entry per dispatched batch, for the engine to
  open records: ("direct", ids, backend, total, wait_s, swap_s,
  link_s, exec_s, complete_s) or ("remote", ids, backend, total,
  token);
* ``completed``  — (ids, token, timing) per finished batch; timing is
  None on the direct path (completion fields were known at dispatch)
  or (wait_s, swap_excess_s, link_s, contention_s, exec_s) measured
  over the fabric, with ``token`` identifying the transit so the
  engine can find the record block it opened at dispatch.

The engine drains them with take_effects() after every submit/handle
call and applies them in order: records, queue insertions, completion
hooks.
"""

import math

import devices
from batcher import DynamicBatcher, PendingRequest
from cluster import select_slot
from equeue import CLASS_COMPLETION, CLASS_DEADLINE
from fabric import FabricEngine
from netsim import dir_payload_bytes
from rustfloat import dur_as_secs_f64, dur_from_secs_f64


class BatchStage:
    """Router-level dynamic batching mapped onto virtual time."""

    def __init__(self, window_s, max_batch):
        assert window_s >= 0.0 and math.isfinite(window_s)
        assert max_batch >= 1
        self.batcher = DynamicBatcher(max_batch, dur_from_secs_f64(window_s), max_batch)
        self.pending = 0

    @staticmethod
    def inst(t_s):
        return dur_from_secs_f64(t_s)

    def enqueue(self, instance, id_, samples, clock_s):
        self.batcher.enqueue(instance, PendingRequest(id_, samples, self.inst(clock_s)))
        self.pending += 1

    def drain_size_ready(self):
        out = []
        while self.batcher.has_size_ready():
            for batch in self.batcher.drain_size_ready():
                self.pending -= len(batch.requests)
                out.append([r.id for r in batch.requests])
        return out

    def drain_ready(self, clock_s):
        now = self.inst(clock_s)
        out = []
        while self.batcher.has_ready(now):
            for batch in self.batcher.drain_ready(now):
                self.pending -= len(batch.requests)
                out.append([r.id for r in batch.requests])
        return out

    def wakeup_at(self, clock_s):
        now = self.inst(clock_s)
        if self.batcher.has_ready(now):
            return clock_s
        d = self.batcher.next_deadline(now)
        if d is None:
            return None
        return max(dur_as_secs_f64(d), clock_s)


class FabricLayer:
    """FabricSpec + engine + continuations + per-device busy clock."""

    def __init__(self, topology, accel_of_backend, n_backends):
        assert len(accel_of_backend) == n_backends
        self.topology = topology
        self.accel_of_backend = accel_of_backend
        self.engine = FabricEngine(topology)
        self.cont = {}  # flow id -> ("in"|"swap"|"out", token)
        self.wake_version = 0
        self.busy_until_s = [0.0] * n_backends

    def is_remote(self, backend):
        return self.topology.is_pooled(self.accel_of_backend[backend])

    def accel(self, backend):
        return self.accel_of_backend[backend]

    def host_of_rank(self, rank):
        return rank % self.topology.hosts

    def ideal_rtt_s(self, bytes_total):
        return self.topology.link.rtt_overhead_s(bytes_total)

    def occupy(self, backend, ready_s, exec_s):
        start_s = max(ready_s, self.busy_until_s[backend])
        done_s = start_s + exec_s
        self.busy_until_s[backend] = done_s
        return start_s - ready_s, done_s

    def set_capacity_scale(self, clock_s, factor):
        """Control plane: degrade/restore every link, re-solve shares."""
        self.engine.set_capacity_scale(clock_s, factor)

    def cancel_flows_of(self, clock_s, token_dead):
        """Control plane: cancel every in-flight flow whose transit
        token satisfies token_dead (its backend left the fleet)."""
        doomed = [fid for fid, cont in self.cont.items() if token_dead(cont[1])]
        for fid in doomed:
            del self.cont[fid]
            self.engine.cancel(clock_s, fid)
        return len(doomed)

    def reset_busy(self, backend):
        """Control plane: forget a departed backend's device horizon."""
        self.busy_until_s[backend] = 0.0

    def drain_wake(self, version, clock_s):
        if version != self.wake_version:
            return None
        done = self.engine.take_completed(clock_s)
        return [self.cont.pop(f) for f in done]

    def next_wake(self, clock_s):
        t = self.engine.next_completion_s()
        if t is None:
            return None
        self.wake_version += 1
        return (max(t, clock_s), self.wake_version)


class Residency:
    """Per-backend LRU model residency (most recently used last)."""

    def __init__(self, slots):
        self.slots = slots
        self.held = []

    def touch(self, model):
        if model in self.held:
            self.held.remove(model)
            self.held.append(model)
            return False
        self.held.append(model)
        if len(self.held) > self.slots:
            self.held.pop(0)
        return True

    def clear(self):
        """Control plane: device memory is gone — forget every model."""
        self.held = []


class Pipeline:
    def __init__(self, backends, policy, hermit_tier, mir_tier, batching,
                 residency=None, fabric=None):
        # batching: None | (window_s, max_batch)
        # residency: None | (slots, swap_s)  -- None = no residency stage
        assert backends, "pipeline needs at least one backend"
        assert hermit_tier, "hermit tier must not be empty"
        assert all(i < len(backends) for i in hermit_tier + mir_tier)
        self.backends = backends
        self.policy = policy
        self.hermit_tier = hermit_tier
        self.mir_tier = mir_tier
        self.hermit_profile = devices.hermit()
        self.mir_profile = devices.mir_noln()
        self.rr_state = [0]
        self.clock_s = 0.0
        self.batcher = BatchStage(*batching) if batching else None
        self.fabric = fabric
        self.residency = ([Residency(residency[0]) for _ in backends]
                          if residency else None)
        self.swap_cfg_s = residency[1] if residency else 0.0
        self.transits = []
        # Dense per-model tables, grown in lockstep by _intern_model
        # (mirrors the Rust hot path's usize-indexed tables; the Rust
        # side's Vec pooling/arena reuse is unobservable and has no
        # transliteration).
        self.models = []         # model id -> name
        self.model_is_mir = []   # model id -> routes to the MIR tier
        self.affinity = []       # model id -> sticky backend (None = unset)
        self.swap_ready_s = []   # [model][backend] landing time
        #                          (-inf = never swapped, +inf = on the wire)
        self.swap_waiters = []   # [model][backend] -> [token]
        self.req_meta = []       # (rank, model id, samples)
        self.submitted = 0
        self.dispatched_n = 0
        self.completed_n = 0
        self.batches = 0
        self.swaps = 0
        self.swap_time_s = 0.0
        # -------- control plane (inert on a static run) --------
        self.active = [True] * len(backends)
        # configured tiers filtered to active backends (rebuilt on
        # every membership change; routing only ever sees these)
        self.live_hermit = list(hermit_tier)
        self.live_mir = list(mir_tier)
        # direct-path batches in flight, indexed by completion token
        # (a token recycles only when its scheduled completion popped)
        self.direct_live = []    # {"ids", "backend", "dead"}
        self.direct_free = []
        # batches with no live backend in their tier, awaiting a join
        self.parked = []         # (ids, retry)
        self.live_batches = [0] * len(backends)
        self.retries_n = 0
        self.orphaned_n = 0
        # effects, in exact legacy push order
        self.scheduled = []      # (t_s, class, pipe_event)
        self.out_dispatched = []
        self.out_completed = []
        self.out_orphaned = []

    # ----------------------------------------------------- effects

    def take_effects(self):
        eff = (self.scheduled, self.out_dispatched, self.out_completed,
               self.out_orphaned)
        self.scheduled, self.out_dispatched, self.out_completed, \
            self.out_orphaned = [], [], [], []
        return eff

    def batcher_pending(self):
        return self.batcher.pending if self.batcher is not None else 0

    def parked_requests(self):
        return sum(len(ids) for ids, _ in self.parked)

    def is_active(self, idx):
        return self.active[idx]

    def active_count(self):
        return sum(1 for a in self.active if a)

    def backlog_s(self, idx):
        return self.backends[idx].queue_s()

    # ----------------------------------------------------- run loop

    def advance_to(self, t_s):
        dt = t_s - self.clock_s
        if dt <= 0.0:
            return
        for b in self.backends:
            b.drain_queue_s(dt)
        self.clock_s = t_s

    def _intern_model(self, model):
        """Dense model id for a name (grows every per-model table)."""
        for mid, name in enumerate(self.models):
            if name == model:
                return mid
        self.models.append(model)
        self.model_is_mir.append(model.startswith("mir"))
        self.affinity.append(None)
        self.swap_ready_s.append([-math.inf] * len(self.backends))
        self.swap_waiters.append([[] for _ in self.backends])
        return len(self.models) - 1

    def request(self, id_):
        """(rank, model name, samples) of a submitted request."""
        rank, mid, samples = self.req_meta[id_]
        return rank, self.models[mid], samples

    def submit(self, rank, model, samples):
        """One request enters the router at the current clock."""
        self.submitted += 1
        mid = self._intern_model(model)
        id_ = len(self.req_meta)
        self.req_meta.append((rank, mid, samples))
        if self.batcher is not None:
            self.batcher.enqueue(model, id_, samples, self.clock_s)
            # Arrival path: dispatch only queues the *size* trigger
            # filled; deadline-expired queues close via their wake-up,
            # after every same-instant arrival.
            for ids in self.batcher.drain_size_ready():
                self._dispatch(ids)
            self._arm_batch_wakeup()
        else:
            self._dispatch([id_])
        return id_

    def handle(self, event):
        kind = event[0]
        if kind == "deadline":
            self._pump_batcher()
        elif kind == "completion":
            self._on_direct_completion(event[1])
        elif kind == "fabric_wake":
            self._on_fabric_wake(event[1])
        elif kind == "xfer_in":
            self._on_xfer_in_done(event[1])
        elif kind == "service_done":
            self._on_service_done(event[1])
        elif kind == "xfer_out":
            self._on_xfer_out_done(event[1])
        else:
            raise ValueError(kind)

    # ------------------------------------------------------ batching

    def _arm_batch_wakeup(self):
        t = self.batcher.wakeup_at(self.clock_s)
        if t is not None:
            self.scheduled.append((t, CLASS_DEADLINE, ("deadline",)))

    def _pump_batcher(self):
        for ids in self.batcher.drain_ready(self.clock_s):
            self._dispatch(ids)
        self._arm_batch_wakeup()

    # ------------------------------------------------------- routing

    def _dispatch(self, ids):
        self._dispatch_inner(ids, False)

    def _dispatch_inner(self, ids, retry):
        rank0, mid, _ = self.req_meta[ids[0]]
        total = sum(self.req_meta[i][2] for i in ids)
        is_mir = self.model_is_mir[mid]
        profile = self.mir_profile if is_mir else self.hermit_profile
        candidates = self.live_mir if is_mir else self.live_hermit
        if not candidates:
            # every backend in the tier has left: park until a join
            self.parked.append((ids, retry))
            return
        if retry:
            self.retries_n += len(ids)
        slot = [self.affinity[mid]]
        idx = select_slot(self.policy, self.backends, self.rr_state, slot,
                          candidates, profile, total)
        self.affinity[mid] = slot[0]
        miss = self.residency[idx].touch(mid) if self.residency is not None else False
        if miss:
            self.swaps += 1
        if self.fabric is not None and self.fabric.is_remote(idx):
            self._dispatch_remote(ids, idx, total, miss, rank0, mid, retry)
            return
        swap_s = self.swap_cfg_s if miss else 0.0
        if miss:
            self.swap_time_s += swap_s
        backend = self.backends[idx]
        wait_s = backend.queue_s()
        link_s = backend.link_overhead_s(profile, total)
        exec_s = backend.execute_s(profile, total)
        latency_s = wait_s + swap_s + (link_s + exec_s)
        occupancy = backend.occupancy_s(profile, total) + swap_s
        backend.add_queue_s(occupancy)
        complete_s = self.clock_s + latency_s
        self.out_dispatched.append(
            ("direct", ids, idx, total, wait_s, swap_s, link_s, exec_s,
             complete_s, retry))
        self.dispatched_n += len(ids)
        self.batches += 1
        self.live_batches[idx] += 1
        if self.direct_free:
            token = self.direct_free.pop()
            self.direct_live[token] = {"ids": ids, "backend": idx, "dead": False}
        else:
            self.direct_live.append({"ids": ids, "backend": idx, "dead": False})
            token = len(self.direct_live) - 1
        self.scheduled.append((complete_s, CLASS_COMPLETION, ("completion", token)))

    def _on_direct_completion(self, token):
        # Stale for batches the control plane orphaned (their ids were
        # re-dispatched already); either way the token is spent.
        batch = self.direct_live[token]
        if batch["dead"]:
            batch["dead"] = False
            self.direct_free.append(token)
            return
        ids = batch["ids"]
        batch["ids"] = []
        idx = batch["backend"]
        self.direct_free.append(token)
        self.live_batches[idx] -= 1
        self._complete(ids, None, None)

    # ------------------------------------------------- fabric phases

    def _dispatch_remote(self, ids, idx, total, miss, rank0, mid, retry):
        profile = self.mir_profile if self.model_is_mir[mid] else self.hermit_profile
        bytes_in, bytes_out = dir_payload_bytes(
            profile.input_elems, profile.output_elems, total)
        fab = self.fabric
        accel = fab.accel(idx)
        host = fab.host_of_rank(rank0)
        ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out)
        # Sized so an uncontended swap takes exactly swap_s at the
        # endpoint's single-stream bandwidth — the degenerate charge.
        swap_bytes = self.swap_cfg_s * fab.topology.link.eff_bandwidth
        # reserve the backend's routing queue now: transfers are
        # explicit, so the batch occupies the device for its execution
        # time only, and policies see committed work immediately (the
        # physical one-batch-at-a-time constraint is occupy's clock)
        backend = self.backends[idx]
        exec_s = backend.execute_s(profile, total)
        backend.add_queue_s(exec_s)
        token = len(self.transits)
        self.out_dispatched.append(("remote", ids, idx, total, token, retry))
        self.dispatched_n += len(ids)
        self.batches += 1
        self.live_batches[idx] += 1
        needs_swap_flow = miss and swap_bytes > 0.0
        if needs_swap_flow:
            # weights are on the wire: same-model followers routed
            # here park until they land
            self.swap_ready_s[mid][idx] = math.inf
        self.transits.append({
            "ids": ids, "backend": idx, "accel": accel, "host": host,
            "model": mid, "bytes_out": bytes_out, "dispatch_s": self.clock_s,
            "net_in_s": 0.0, "in_done_s": 0.0,
            "in_done": False, "swap_done": not needs_swap_flow, "started": False,
            "dead": False,
            "swap_excess_s": 0.0, "wait_s": 0.0, "exec_s": exec_s,
            "out_start_s": 0.0, "ideal_rtt_s": ideal_rtt_s,
        })
        path = fab.topology.request_path(host, accel)
        flow = fab.engine.start(self.clock_s, path, bytes_in)
        fab.cont[flow] = ("in", token)
        if needs_swap_flow:
            spath = fab.topology.swap_path(accel)
            sflow = fab.engine.start(self.clock_s, spath, swap_bytes)
            fab.cont[sflow] = ("swap", token)
        self._arm_fabric()

    def _arm_fabric(self):
        armed = self.fabric.next_wake(self.clock_s)
        if armed is not None:
            t, version = armed
            self.scheduled.append((t, CLASS_COMPLETION, ("fabric_wake", version)))

    def _on_fabric_wake(self, version):
        fab = self.fabric
        conts = fab.drain_wake(version, self.clock_s)
        if conts is None:
            return  # stale: a newer wake-up is armed
        for kind, token in conts:
            if kind == "in":
                fixed = fab.topology.dir_fixed_s(self.transits[token]["accel"])
                self.scheduled.append((self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_in", token)))
            elif kind == "swap":
                measured = self.clock_s - self.transits[token]["dispatch_s"]
                self.swap_time_s += measured
                self.transits[token]["swap_done"] = True
                # the weights landed: unblock this batch, then every
                # same-model follower parked behind it
                mid = self.transits[token]["model"]
                idx = self.transits[token]["backend"]
                self.swap_ready_s[mid][idx] = self.clock_s
                self._try_begin_service(token)
                waiters = self.swap_waiters[mid][idx]
                self.swap_waiters[mid][idx] = []
                for waiter in waiters:
                    self._try_begin_service(waiter)
            else:  # out
                fixed = fab.topology.dir_fixed_s(self.transits[token]["accel"])
                self.scheduled.append((self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_out", token)))
        self._arm_fabric()

    def _on_xfer_in_done(self, token):
        tr = self.transits[token]
        if tr["dead"]:
            return
        tr["net_in_s"] = self.clock_s - tr["dispatch_s"]
        tr["in_done_s"] = self.clock_s
        tr["in_done"] = True
        self._try_begin_service(token)

    def _try_begin_service(self, token):
        clock = self.clock_s
        tr = self.transits[token]
        if tr["dead"] or tr["started"] or not (tr["in_done"] and tr["swap_done"]):
            return
        # == +inf exactly: -inf means the model was never swapped here
        if self.swap_ready_s[tr["model"]][tr["backend"]] == math.inf:
            self.swap_waiters[tr["model"]][tr["backend"]].append(token)
            return
        wait_s, done_s = self.fabric.occupy(tr["backend"], clock, tr["exec_s"])
        # Re-sync the routing signal with the device horizon: long
        # transfers/swaps can outlive the dispatch-time reservation's
        # wall-time drain.
        backend = self.backends[tr["backend"]]
        deficit = (done_s - clock) - backend.queue_s()
        if deficit > 0.0:
            backend.add_queue_s(deficit)
        tr["started"] = True
        tr["swap_excess_s"] = clock - tr["in_done_s"]
        tr["wait_s"] = wait_s
        self.scheduled.append((done_s, CLASS_COMPLETION, ("service_done", token)))

    def _on_service_done(self, token):
        tr = self.transits[token]
        if tr["dead"]:
            return
        tr["out_start_s"] = self.clock_s
        fab = self.fabric
        path = fab.topology.response_path(tr["host"], tr["accel"])
        flow = fab.engine.start(self.clock_s, path, tr["bytes_out"])
        fab.cont[flow] = ("out", token)
        self._arm_fabric()

    def _on_xfer_out_done(self, token):
        tr = self.transits[token]
        if tr["dead"]:
            return
        net_out_s = self.clock_s - tr["out_start_s"]
        link_s = tr["net_in_s"] + net_out_s
        contention_s = max(link_s - tr["ideal_rtt_s"], 0.0)
        timing = (tr["wait_s"], tr["swap_excess_s"], link_s, contention_s, tr["exec_s"])
        ids = tr["ids"]
        tr["ids"] = []
        self.live_batches[tr["backend"]] -= 1
        self._complete(ids, token, timing)

    def _complete(self, ids, token, timing):
        self.completed_n += len(ids)
        self.out_completed.append((ids, token, timing))

    # ------------------------------------------------- control plane

    def _rebuild_live_tiers(self):
        self.live_hermit = [i for i in self.hermit_tier if self.active[i]]
        self.live_mir = [i for i in self.mir_tier if self.active[i]]

    def control_backend_leave(self, idx):
        """Backend idx leaves the fleet (failure or scale-down): queue
        drained, residency/weights-ready gates invalidated, flows
        cancelled, in-flight batches orphaned and re-dispatched once
        onto the surviving tier (or parked when the tier emptied)."""
        assert idx < len(self.backends), f"unknown backend {idx}"
        if not self.active[idx]:
            return
        self.active[idx] = False
        self._rebuild_live_tiers()
        # sticky affinity must not keep pointing at the dead slot
        for mid, slot in enumerate(self.affinity):
            if slot == idx:
                self.affinity[mid] = None
        # drain the dead backend's routing queue: its committed work
        # is exactly the in-flight set being orphaned below
        q = self.backends[idx].queue_s()
        if q > 0.0:
            self.backends[idx].drain_queue_s(q)
        # residency + weights-ready gates: device memory is gone
        if self.residency is not None:
            self.residency[idx].clear()
        for mid in range(len(self.models)):
            self.swap_ready_s[mid][idx] = -math.inf
            self.swap_waiters[mid][idx] = []
        # orphan every batch the backend held, direct then fabric,
        # ascending token order (deterministic re-dispatch order)
        orphans = []
        for batch in self.direct_live:
            if batch["backend"] == idx and not batch["dead"] and batch["ids"]:
                batch["dead"] = True
                orphans.append(batch["ids"])
                batch["ids"] = []
        for tr in self.transits:
            if tr["backend"] == idx and not tr["dead"] and tr["ids"]:
                tr["dead"] = True
                orphans.append(tr["ids"])
                tr["ids"] = []
        if self.fabric is not None:
            self.fabric.cancel_flows_of(
                self.clock_s, lambda token: self.transits[token]["dead"])
            self.fabric.reset_busy(idx)
            self._arm_fabric()
        self.live_batches[idx] = 0
        for ids in orphans:
            self.orphaned_n += len(ids)
            self.out_orphaned.extend(ids)
            self._dispatch_inner(ids, True)

    def control_backend_join(self, idx):
        """Backend idx (re)joins the fleet cold; parked batches flush
        through the router in arrival order."""
        assert idx < len(self.backends), f"unknown backend {idx}"
        if self.active[idx]:
            return
        self.active[idx] = True
        self._rebuild_live_tiers()
        parked = self.parked
        self.parked = []
        for ids, retry in parked:
            self._dispatch_inner(ids, retry)

    def control_link_scale(self, factor):
        """Scale every fabric link to factor x as-built capacity and
        re-solve the fair shares (no-op on the fabric-less path)."""
        if self.fabric is not None:
            self.fabric.set_capacity_scale(self.clock_s, factor)
            self._arm_fabric()
