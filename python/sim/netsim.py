"""netsim transliteration: the Link model."""

import math

INF = math.inf


class Link:
    __slots__ = ("wire_latency_s", "soft_per_msg_s", "eff_bandwidth", "line_rate", "async_overlap")

    def __init__(self, wire_latency_s, soft_per_msg_s, eff_bandwidth, line_rate, async_overlap):
        self.wire_latency_s = wire_latency_s
        self.soft_per_msg_s = soft_per_msg_s
        self.eff_bandwidth = eff_bandwidth
        self.line_rate = line_rate
        self.async_overlap = async_overlap

    @staticmethod
    def infiniband_cx6():
        return Link(1e-6, 8e-6, 2.1e9, 100e9 / 8.0, 0.5)

    @staticmethod
    def local():
        return Link(0.0, 0.0, INF, INF, 1.0)

    def clone(self):
        return Link(
            self.wire_latency_s,
            self.soft_per_msg_s,
            self.eff_bandwidth,
            self.line_rate,
            self.async_overlap,
        )

    def rtt_overhead_s(self, bytes_total):
        if bytes_total > 0.0 and math.isfinite(self.eff_bandwidth):
            transfer_s = bytes_total / self.eff_bandwidth
        else:
            transfer_s = 0.0
        return 2.0 * self.wire_latency_s + self.soft_per_msg_s + transfer_s

    def dir_fixed_s(self):
        return self.wire_latency_s + 0.5 * self.soft_per_msg_s


def payload_bytes(input_elems, output_elems, batch):
    return 2.0 * float(input_elems + output_elems) * float(batch)


def dir_payload_bytes(input_elems, output_elems, batch):
    return (2.0 * float(input_elems) * float(batch), 2.0 * float(output_elems) * float(batch))
