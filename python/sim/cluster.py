"""cluster transliteration: Backend impls, policies, Cluster."""

import math

import devices
import rdu as rdu_mod
from netsim import Link, payload_bytes

ROUND_ROBIN = "round_robin"
LEAST_OUTSTANDING = "least_outstanding"
MODEL_AFFINITY = "model_affinity"
LATENCY_AWARE = "latency_aware"

ALL_POLICIES = [ROUND_ROBIN, LEAST_OUTSTANDING, MODEL_AFFINITY, LATENCY_AWARE]


class BackendBase:
    def __init__(self, name, link):
        self.name = name
        self.link = link
        self.queue_s_v = 0.0

    def queue_s(self):
        return self.queue_s_v

    def add_queue_s(self, s):
        self.queue_s_v += s

    def drain_queue_s(self, dt):
        self.queue_s_v = max(self.queue_s_v - dt, 0.0)

    def link_overhead_s(self, model, batch):
        return self.link.rtt_overhead_s(
            payload_bytes(model.input_elems, model.output_elems, batch)
        )

    def latency_s(self, model, batch):
        return self.link_overhead_s(model, batch) + self.execute_s(model, batch)

    def occupancy_s(self, model, batch):
        return (self.execute_s(model, batch)
                + self.link_overhead_s(model, batch) * (1.0 - self.link.async_overlap))


class GpuBackend(BackendBase):
    def __init__(self, name, gpu, api, link=None):
        super().__init__(name, link if link is not None else Link.local())
        self.gpu = gpu
        self.api = api

    def execute_s(self, model, batch):
        return devices.GpuModel(self.gpu, self.api, model).latency_s(batch)


class RduBackend(BackendBase):
    def __init__(self, name, tiles, api, link=None):
        super().__init__(name, link if link is not None else Link.infiniband_cx6())
        self.tiles = tiles
        self.api = api

    def execute_s(self, model, batch):
        return rdu_mod.RduModel(model, self.tiles, self.api).latency_best_s(batch)


def _least_queued(backends, candidates):
    best = candidates[0]
    best_queue = math.inf
    for idx in candidates:
        q = backends[idx].queue_s()
        if q < best_queue:
            best = idx
            best_queue = q
    return best


def select(policy, backends, rr_state, affinity, candidates, instance, profile, batch):
    """policy::select; rr_state is a 1-element list (the cursor)."""
    slot = [affinity.get(instance)]
    idx = select_slot(policy, backends, rr_state, slot, candidates, profile, batch)
    if slot[0] is not None:
        affinity[instance] = slot[0]
    return idx


def select_slot(policy, backends, rr_state, affinity_slot, candidates, profile, batch):
    """policy::select_slot — the hot-path entry taking the caller's
    dense per-model affinity slot (a 1-element list) instead of a
    name-keyed map."""
    assert candidates
    if policy == ROUND_ROBIN:
        idx = candidates[rr_state[0] % len(candidates)]
        rr_state[0] += 1
        return idx
    if policy == LEAST_OUTSTANDING:
        return _least_queued(backends, candidates)
    if policy == MODEL_AFFINITY:
        idx = affinity_slot[0]
        if idx is not None and idx in candidates:
            return idx
        idx = _least_queued(backends, candidates)
        affinity_slot[0] = idx
        return idx
    if policy == LATENCY_AWARE:
        best = candidates[0]
        best_cost = math.inf
        for idx in candidates:
            b = backends[idx]
            cost = b.queue_s() + b.latency_s(profile, batch)
            if cost < best_cost:
                best = idx
                best_cost = cost
        return best
    raise ValueError(policy)


class Cluster:
    def __init__(self, backends, policy):
        assert backends
        self.backends = backends
        self.policy = policy
        self.rr_state = [0]
        self.affinity = {}
        self.stats = [[0, 0, 0.0] for _ in backends]  # requests, samples, busy_s
        self.clock_s = 0.0
        self.last_completion_s = 0.0

    def advance_to(self, t_s):
        dt = t_s - self.clock_s
        if dt <= 0.0:
            return
        for b in self.backends:
            b.drain_queue_s(dt)
        self.clock_s = t_s

    def submit_among(self, candidates, instance, profile, samples):
        idx = select(self.policy, self.backends, self.rr_state, self.affinity,
                     candidates, instance, profile, samples)
        backend = self.backends[idx]
        wait_s = backend.queue_s()
        link_overhead_s = backend.link_overhead_s(profile, samples)
        latency_s = wait_s + backend.latency_s(profile, samples)
        occupancy = backend.occupancy_s(profile, samples)
        backend.add_queue_s(occupancy)
        st = self.stats[idx]
        st[0] += 1
        st[1] += samples
        st[2] += occupancy
        self.last_completion_s = max(self.last_completion_s, self.clock_s + latency_s)
        return idx, wait_s, latency_s, link_overhead_s

    def makespan_s(self):
        return max(self.last_completion_s, self.clock_s)
