"""harness::fluid transliteration: the steady-state fluid tier.

Mirrors rust/src/fluid/mod.rs op-for-op so the scale golden is
byte-exact.  The fluid tier solves one cognitive-simulation timestep in
closed form on top of the analytic backend service models and a
max-min-fair burst abstraction of the pooled fabric:

  * requests are aggregated into per-model batches (the batching-window
    correction), split over homogeneous fleet *classes* by the routing
    policy's steady-state weights;
  * each backend serves its share of batches serially; LRU swap cost
    enters as a steady-state miss rate (IRM: ``1 - slots/models`` per
    backend, with the model-affinity exception);
  * the request burst and the staggered response stream cross the
    fabric at max-min burst rates; the response concurrency is a damped
    fixed point (completions arrive at the pool's service rate, so the
    number of in-flight response flows must be self-consistent with the
    per-flow rate they imply).

The fluid tier models the hermit (hydra) stream only; MIR traffic is
out of scope (validation always runs with ``mir_every = 0``).
"""

import math

import devices
import rdu as rdu_mod
from campaign import fixed3, us
from cluster import MODEL_AFFINITY, ROUND_ROBIN, GpuBackend, RduBackend
from netsim import Link
from rustfloat import rust_round

FIXED_POINT_MAX_ITERS = 64
FIXED_POINT_TOL = 1e-9
FIXED_POINT_DAMPING = 0.5

# The fluid-vs-event TTS bound the anchor cells re-validate at
# scale-out rank counts (the same 15 % contract fluid_props pins on
# the 32-rank campaign grid; measured ~0.1 % on the swap-free anchors).
ANCHOR_TTS_BOUND = 0.15


def fleet_classes(topology, ranks, fleet, pool_link):
    """Homogeneous (count, backend) classes of the hermit tier.

    Local: every rank owns an identical A100/TRT-CG, so one class of
    ``ranks`` members with a zero-cost link.  Pooled/hybrid: the pool
    members grouped by identical shape — the default fleet is the
    4-tile-C++ / 2-tile-Python pair; ("mixed", G, R) is G remote GPUs
    plus ceil(R/2) 4-tile and floor(R/2) 2-tile groups (the alternating
    pool_members construction collapsed to class counts).
    """
    if topology == "local":
        return [(ranks, GpuBackend("gpu/local", devices.Gpu.a100(),
                                   devices.TRT_CUDA_GRAPHS))]
    if fleet == "default":
        return [
            (1, RduBackend("rdu/pool0", 4, rdu_mod.RDU_CPP_OPT, pool_link.clone())),
            (1, RduBackend("rdu/pool1", 2, rdu_mod.RDU_PYTHON, pool_link.clone())),
        ]
    _, gpus, rdus = fleet
    assert gpus + rdus >= 1
    classes = []
    if gpus > 0:
        classes.append((gpus, GpuBackend("gpu/pool", devices.Gpu.a100(),
                                         devices.TRT_CUDA_GRAPHS, pool_link.clone())))
    four_tile = (rdus + 1) // 2
    two_tile = rdus // 2
    if four_tile > 0:
        classes.append((four_tile, RduBackend("rdu/pool-4t", 4, rdu_mod.RDU_CPP_OPT,
                                              pool_link.clone())))
    if two_tile > 0:
        classes.append((two_tile, RduBackend("rdu/pool-2t", 2, rdu_mod.RDU_PYTHON,
                                             pool_link.clone())))
    return classes


def burst_rate(nic, oversub, flows, n_src, n_dst):
    """Per-flow max-min rate for a symmetric burst of `flows` flows.

    Mirrors the pooled/hybrid capacity layout: per-source NIC ports,
    source aggregation at n_src*nic/oversub, destination aggregation at
    n_dst*nic/oversub, per-destination NIC ports.  With the flows
    spread evenly, each port carries flows/n of them.
    """
    per_src = nic / max(1.0, flows / float(n_src))
    src_agg = float(n_src) * nic / oversub / flows
    dst_agg = float(n_dst) * nic / oversub / flows
    per_dst = nic / max(1.0, flows / float(n_dst))
    return min(min(per_src, src_agg), min(dst_agg, per_dst))


def solve_cell(topology, policy, ranks, models, swap_s, overlap, oversub, cfg,
               fleet="default"):
    """Solve one grid cell in closed form; returns a summary dict whose
    keys mirror FluidSummary (seconds units, like cog summaries)."""
    profile = devices.hermit()
    pool_link = Link.infiniband_cx6()
    classes = fleet_classes(topology, ranks, fleet, pool_link)
    n_backends = sum(c for c, _ in classes)

    lo, hi = cfg["samples_per_request"]
    s_mean = (float(lo) + float(hi)) / 2.0
    requests_per_step = float(ranks) * float(cfg["requests_per_step"])
    window_s = cfg["window_us"] * 1e-6

    # -- batching-window correction: per-model aggregation ------------
    if window_s > 0.0:
        samples_m = requests_per_step * s_mean / float(models)
        batches_m = max(1.0, samples_m / float(cfg["max_batch"]))
        window_wait = window_s if samples_m < float(cfg["max_batch"]) else 0.0
        total_batches = float(models) * batches_m
        batch_sizes = [max(1, int(rust_round(samples_m / batches_m)))]
        mean_batch = float(batch_sizes[0])
    else:
        # window off: every request is its own batch; service values
        # are expectations over the integer sample distribution
        total_batches = requests_per_step
        window_wait = 0.0
        batch_sizes = list(range(int(lo), int(hi) + 1))
        mean_batch = s_mean

    # -- per-class service rates (averaged over batch sizes) ----------
    def averaged(f):
        total = 0.0
        for b in batch_sizes:
            total += f(b)
        return total / float(len(batch_sizes))

    execs = [averaged(lambda b, be=backend: be.execute_s(profile, b))
             for _, backend in classes]
    occs = [averaged(lambda b, be=backend: be.occupancy_s(profile, b))
            for _, backend in classes]
    link_ohs = [averaged(lambda b, be=backend: be.link_overhead_s(profile, b))
                for _, backend in classes]

    # -- routing-policy load split ------------------------------------
    # The cursor policy deals batches evenly; queue/latency-aware
    # policies equalise backlog, so class load goes with
    # count/occupancy.  Model affinity assigns each model to the
    # least-queued backend at first touch, which is also speed-biased,
    # and concentrates the whole stream on at most `models` backends.
    # Affinity assignment happens at first touch, when every request
    # misses: the queue the assignment reads includes the swap charge,
    # so the speed bias washes out as swap_s grows.
    weights = []
    for (count, _), occ in zip(classes, occs):
        if policy == ROUND_ROBIN:
            weights.append(float(count))
        elif policy == MODEL_AFFINITY:
            weights.append(float(count) / (occ + swap_s))
        else:
            weights.append(float(count) / occ)
    wsum = 0.0
    for w in weights:
        wsum += w

    slots = float(cfg["residency_slots"])
    per_backend_batches = []
    per_backend_models = []
    loaded_per_class = []
    for (count, _), w in zip(classes, weights):
        share = w / wsum
        if policy == MODEL_AFFINITY:
            loaded = min(float(count), float(models) * share)
        else:
            loaded = float(count)
        loaded_per_class.append(loaded)
        per_backend_batches.append(total_batches * share / loaded)
        per_backend_models.append(float(models) * share / loaded)
    loaded_total = 0.0
    for l in loaded_per_class:
        loaded_total += l

    # -- steady-state LRU miss rate (IRM) -----------------------------
    # Under round-robin / least-outstanding / latency-aware routing a
    # backend eventually sees the whole model population, so the LRU
    # hit ratio is slots/models (uniform IRM); model affinity pins each
    # model to one backend, leaving models/loaded distinct models per
    # loaded backend.
    # -- straggler corrections ----------------------------------------
    # The barrier ends a step at the MAX over backends, so the
    # bottleneck backend carries a Gumbel-style excess over the mean:
    # miss counts fluctuate binomially under cursor routing (fully for
    # round-robin, half-damped when backlog-aware policies reshuffle
    # load away from unlucky backends), and affinity's first-touch
    # assignment leaves a multinomial imbalance in both batches and
    # models per backend.
    ln_loaded = math.log(loaded_total) if loaded_total > 1.0 else 0.0

    def multinomial_max(mean):
        if ln_loaded == 0.0:
            return mean
        return mean + math.sqrt(mean * (1.0 - 1.0 / loaded_total) * ln_loaded)

    def lru_miss(models_per_backend):
        if models_per_backend <= slots:
            return 0.0
        return 1.0 - slots / models_per_backend

    misses = []
    misses_strag = []
    for m_b in per_backend_models:
        if policy == MODEL_AFFINITY:
            misses.append(lru_miss(m_b))
            misses_strag.append(lru_miss(multinomial_max(m_b)))
        else:
            misses.append(lru_miss(float(models)))
            misses_strag.append(lru_miss(float(models)))
    miss_mean = 0.0
    for (count, _), loaded, m in zip(classes, loaded_per_class, misses):
        miss_mean += m * loaded
    miss_mean = miss_mean / loaded_total

    def straggler_miss(i, b):
        p = misses_strag[i]
        if policy == MODEL_AFFINITY or p <= 0.0 or p >= 1.0 or ln_loaded == 0.0:
            return p
        damping = 1.0 if policy == ROUND_ROBIN else 0.5
        return min(1.0, p + damping * math.sqrt(p * (1.0 - p) * ln_loaded / b))

    def straggler_batches(b):
        if policy != MODEL_AFFINITY:
            return b
        return multinomial_max(b)

    # -- swap cost per miss -------------------------------------------
    # Direct (local) dispatch charges swap_s on the backend.  Over the
    # fabric a swap is a weight transfer of swap_s * nic bytes down the
    # shared swap path, so its duration stretches with oversubscription
    # and with the number of concurrently-swapping pool members.
    if topology == "local" or swap_s <= 0.0:
        swap_cost = swap_s
    else:
        concurrency = 1.0 + miss_mean * (float(n_backends) - 1.0)
        swap_cost = swap_s * max(1.0, oversub * concurrency / float(n_backends))

    # -- fabric burst phase (pooled / hybrid only) --------------------
    fixed_point_iterations = 0
    converged = True
    if topology == "local":
        t_in = 0.0
        t_out = 0.0
        dir_fixed = 0.0
    else:
        nic = pool_link.eff_bandwidth
        in_bytes = 2.0 * float(profile.input_elems) * mean_batch
        out_bytes = 2.0 * float(profile.output_elems) * mean_batch
        rate_in = burst_rate(nic, oversub, total_batches, ranks, n_backends)
        t_in = in_bytes / rate_in
        # pool service rate in batches/s: completions leave at mu, so
        # in-flight response flows F satisfy F = mu * out_bytes/rate(F)
        mu = 0.0
        for (count, _), ex, m in zip(classes, execs, misses):
            mu += float(count) / (ex + m * swap_cost)
        flows = 1.0
        converged = False
        for _ in range(FIXED_POINT_MAX_ITERS):
            fixed_point_iterations += 1
            rate = burst_rate(nic, oversub, flows, n_backends, ranks)
            target = mu * out_bytes / rate
            if target < 1.0:
                target = 1.0
            if target > total_batches:
                target = total_batches
            nxt = FIXED_POINT_DAMPING * flows + (1.0 - FIXED_POINT_DAMPING) * target
            if abs(nxt - flows) < FIXED_POINT_TOL:
                flows = nxt
                converged = True
                break
            flows = nxt
        t_out = out_bytes / burst_rate(nic, oversub, flows, n_backends, ranks)
        dir_fixed = pool_link.dir_fixed_s()

    # -- per-class inference phase (straggler backend) ----------------
    phases = []
    queues = []
    nets = []
    swaps = []
    for i, ((count, backend), b_c) in enumerate(zip(classes, per_backend_batches)):
        b_strag = straggler_batches(b_c)
        p_strag = straggler_miss(i, max(b_c, 1.0))
        if topology == "local":
            gap = occs[i] + p_strag * swap_cost
            net = link_ohs[i]
        else:
            gap = execs[i] + p_strag * swap_cost
            net = t_in + dir_fixed + t_out + dir_fixed
        queue = window_wait + max(0.0, b_strag - 1.0) * gap
        phase = queue + p_strag * swap_cost + net + execs[i]
        phases.append(phase)
        queues.append(queue)
        nets.append(net)
        swaps.append(p_strag * swap_cost)

    bottleneck_idx = 0
    for i in range(1, len(phases)):
        if phases[i] > phases[bottleneck_idx]:
            bottleneck_idx = i
    phase_max = phases[bottleneck_idx]

    # -- step assembly (mirrors the cogsim emit model) ----------------
    compute = cfg["compute_s"]
    emit_offset = (1.0 - overlap) * compute
    step = max(compute, emit_offset + phase_max)
    timesteps = cfg["timesteps"]
    tts = step * float(timesteps)

    # -- request quantiles: weighted per-batch-position latencies -----
    entries = []
    for i, ((count, _), b_c) in enumerate(zip(classes, per_backend_batches)):
        if topology == "local":
            gap = occs[i] + misses[i] * swap_cost
        else:
            gap = execs[i] + misses[i] * swap_cost
        base = window_wait + misses[i] * swap_cost + nets[i] + execs[i]
        k = 0
        while True:
            weight = loaded_per_class[i] * min(1.0, b_c - float(k))
            if weight <= 0.0:
                break
            entries.append((base + float(k) * gap, weight))
            k += 1
    entries.sort(key=lambda e: e[0])
    total_weight = 0.0
    for _, w in entries:
        total_weight += w

    def weighted_quantile(q):
        thresh = q / 100.0 * total_weight
        cum = 0.0
        for latency, w in entries:
            cum += w
            if cum >= thresh:
                return latency
        return entries[-1][0]

    p50 = weighted_quantile(50.0)
    p99 = weighted_quantile(99.0)

    return {
        "ranks": ranks,
        "timesteps": timesteps,
        "requests": ranks * cfg["requests_per_step"] * timesteps,
        "samples": int(rust_round(requests_per_step * s_mean)) * timesteps,
        "batches": int(rust_round(total_batches)) * timesteps,
        "time_to_solution_s": tts,
        "mean_step_s": step,
        "total_compute_s": emit_offset * float(timesteps),
        "total_queue_s": queues[bottleneck_idx] * float(timesteps),
        "total_swap_s": swaps[bottleneck_idx] * float(timesteps),
        "total_network_s": nets[bottleneck_idx] * float(timesteps),
        "total_service_s": execs[bottleneck_idx] * float(timesteps),
        "p50_s": p50,
        "p99_s": p99,
        "fixed_point_iterations": fixed_point_iterations,
        "converged": converged,
        "bottleneck": classes[bottleneck_idx][1].name,
    }


# ------------------------------------------------------ scale campaign


def default_scale_cfg():
    return {
        "rank_counts": [64, 256, 1024, 4096, 16384],
        "pool_sizes": [8, 16, 32, 64, 128, 256, 512],
        "policy": ROUND_ROBIN,
        "oversub": 4.0,
        "models_per_rank": 8,
        "swap_s": 2e-3,
        "overlap": 0.0,
        "timesteps": 8,
        "compute_s": 2e-3,
        "requests_per_step": 6,
        "samples_per_request": (2, 3),
        "residency_slots": 4,
        "window_us": 0.0,
        "max_batch": 256,
        "anchor_rank_counts": [64, 256],
    }


def smoke_scale_cfg():
    cfg = default_scale_cfg()
    cfg["rank_counts"] = [64, 1024]
    cfg["pool_sizes"] = [8, 64]
    cfg["anchor_rank_counts"] = [64]
    return cfg


def run_scale_campaign(cfg):
    rows = []
    for ranks in cfg["rank_counts"]:
        local = solve_cell("local", cfg["policy"], ranks, cfg["models_per_rank"],
                           cfg["swap_s"], cfg["overlap"], 1.0, cfg)
        pools = []
        crossover = None
        for pool in cfg["pool_sizes"]:
            s = solve_cell("pooled", cfg["policy"], ranks, cfg["models_per_rank"],
                           cfg["swap_s"], cfg["overlap"], cfg["oversub"], cfg,
                           fleet=("mixed", 0, pool))
            pools.append((pool, s))
            if crossover is None and s["time_to_solution_s"] <= local["time_to_solution_s"]:
                crossover = pool
        rows.append({"ranks": ranks, "local": local, "pools": pools,
                     "crossover_pool": crossover})
    return {"config": cfg, "rows": rows, "anchors": []}


def run_scale_anchors(cfg):
    """Mirrors fluid::run_scale_anchors: for each anchor rank count the
    coupled event-for-event engine and the fluid tier solve the same
    swap-free pooled cell (default pool fleet, the campaign's
    oversubscription and knobs) and the TTS pair is recorded."""
    import campaign as cp
    cog = cp.default_cog_cfg()
    cog.update(timesteps=cfg["timesteps"], compute_s=cfg["compute_s"],
               requests_per_step=cfg["requests_per_step"],
               samples_per_request=cfg["samples_per_request"],
               residency_slots=cfg["residency_slots"],
               window_us=cfg["window_us"], max_batch=cfg["max_batch"])
    anchors = []
    for ranks in cfg["anchor_rank_counts"]:
        ev = cp.run_cog_scenario("pooled", cfg["policy"], ranks,
                                 cfg["models_per_rank"], 0.0, cfg["overlap"],
                                 cfg["oversub"], cog)
        fl = solve_cell("pooled", cfg["policy"], ranks, cfg["models_per_rank"],
                        0.0, cfg["overlap"], cfg["oversub"], cfg)
        anchors.append({
            "ranks": ranks,
            "oversub": cfg["oversub"],
            "swap_s": 0.0,
            "event_tts_s": ev["summary"]["time_to_solution_s"],
            "fluid_tts_s": fl["time_to_solution_s"],
        })
    return anchors


def run_scale_campaign_with_anchors(cfg):
    result = run_scale_campaign(cfg)
    result["anchors"] = run_scale_anchors(cfg)
    return result


# ------------------------------------------------------------- JSON


def fluid_summary_json(s):
    return {
        "ranks": float(s["ranks"]),
        "timesteps": float(s["timesteps"]),
        "requests": float(s["requests"]),
        "samples": float(s["samples"]),
        "batches": float(s["batches"]),
        "time_to_solution_us": us(s["time_to_solution_s"]),
        "mean_step_us": us(s["mean_step_s"]),
        "total_compute_us": us(s["total_compute_s"]),
        "total_queue_us": us(s["total_queue_s"]),
        "total_swap_us": us(s["total_swap_s"]),
        "total_network_us": us(s["total_network_s"]),
        "total_service_us": us(s["total_service_s"]),
        "request_p50_us": us(s["p50_s"]),
        "request_p99_us": us(s["p99_s"]),
        "fixed_point_iterations": float(s["fixed_point_iterations"]),
        "converged": bool(s["converged"]),
        "bottleneck": s["bottleneck"],
    }


def scale_config_json(cfg):
    return {
        "rank_counts": [float(r) for r in cfg["rank_counts"]],
        "pool_sizes": [float(p) for p in cfg["pool_sizes"]],
        "policy": cfg["policy"],
        "oversub": fixed3(cfg["oversub"]),
        "models_per_rank": float(cfg["models_per_rank"]),
        "swap_us": us(cfg["swap_s"]),
        "overlap": fixed3(cfg["overlap"]),
        "timesteps": float(cfg["timesteps"]),
        "compute_us": us(cfg["compute_s"]),
        "requests_per_step": float(cfg["requests_per_step"]),
        "samples_per_request": [float(cfg["samples_per_request"][0]),
                                float(cfg["samples_per_request"][1])],
        "residency_slots": float(cfg["residency_slots"]),
        "window_us": fixed3(cfg["window_us"]),
        "max_batch": float(cfg["max_batch"]),
        "anchor_rank_counts": [float(r) for r in cfg["anchor_rank_counts"]],
    }


def scale_anchor_json(a):
    err = a["fluid_tts_s"] / a["event_tts_s"] - 1.0
    return {
        "ranks": float(a["ranks"]),
        "oversub": fixed3(a["oversub"]),
        "swap_us": us(a["swap_s"]),
        "event_tts_us": us(a["event_tts_s"]),
        "fluid_tts_us": us(a["fluid_tts_s"]),
        "tts_error": fixed3(err),
        "within_bound": abs(err) <= ANCHOR_TTS_BOUND,
    }


def scale_row_json(row):
    local_tts = row["local"]["time_to_solution_s"]
    return {
        "ranks": float(row["ranks"]),
        "local": fluid_summary_json(row["local"]),
        "pools": [
            {
                "pool": float(pool),
                "speedup_vs_local": fixed3(local_tts / s["time_to_solution_s"]),
                "summary": fluid_summary_json(s),
            }
            for pool, s in row["pools"]
        ],
        "crossover_pool": (float(row["crossover_pool"])
                           if row["crossover_pool"] is not None else None),
    }


def scale_campaign_json(result):
    return {
        "config": scale_config_json(result["config"]),
        "rows": [scale_row_json(r) for r in result["rows"]],
        "anchors": [scale_anchor_json(a) for a in result["anchors"]],
    }
