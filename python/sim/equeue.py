"""eventsim::equeue transliteration: (time, class, seq) min-heap."""

import heapq
import math

CLASS_COMPLETION = 0
CLASS_ARRIVAL = 1
CLASS_DEADLINE = 2


class EventQueue:
    __slots__ = ("heap", "seq")

    def __init__(self):
        self.heap = []
        self.seq = 0

    def push(self, time_s, event):
        self.push_class(time_s, CLASS_ARRIVAL, event)

    def push_class(self, time_s, class_, event):
        assert math.isfinite(time_s) and time_s >= 0.0, f"bad event time {time_s}"
        heapq.heappush(self.heap, (time_s, class_, self.seq, event))
        self.seq += 1

    def pop(self):
        if not self.heap:
            return None
        t, _, _, event = heapq.heappop(self.heap)
        return (t, event)

    def peek_time(self):
        return self.heap[0][0] if self.heap else None

    def __len__(self):
        return len(self.heap)
