"""util::json writer transliteration: compact, BTreeMap key order,
Rust `{}` float formatting."""

from rustfloat import fmt_f64


def write(value):
    out = []
    _write_into(value, out)
    return "".join(out)


def _write_into(value, out):
    if value is None:
        out.append("null")
    elif value is True:
        out.append("true")
    elif value is False:
        out.append("false")
    elif isinstance(value, (int, float)):
        out.append(fmt_f64(float(value)))
    elif isinstance(value, str):
        _write_escaped(value, out)
    elif isinstance(value, list):
        out.append("[")
        for i, item in enumerate(value):
            if i > 0:
                out.append(",")
            _write_into(item, out)
        out.append("]")
    elif isinstance(value, dict):
        out.append("{")
        for i, k in enumerate(sorted(value.keys())):
            if i > 0:
                out.append(",")
            _write_escaped(k, out)
            out.append(":")
            _write_into(value[k], out)
        out.append("}")
    else:
        raise TypeError(type(value))


def _write_escaped(s, out):
    out.append('"')
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append(f"\\u{ord(c):04x}")
        else:
            out.append(c)
    out.append('"')
