"""Generate the committed campaign goldens byte-exactly.

Writes rust/tests/golden/{campaign,event,cogsim,control,scale}_summary
.json from the default configs — the same documents
`cargo test --test campaign_golden` (and the control-plane suite)
reproduces and compares.
"""

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import campaign  # noqa: E402
import control  # noqa: E402
import fluid  # noqa: E402
import jsonw  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
GOLDEN = os.path.join(REPO, "rust", "tests", "golden")


def main():
    os.makedirs(GOLDEN, exist_ok=True)
    t0 = time.time()

    doc = jsonw.write(campaign.campaign_json(campaign.run_campaign(
        campaign.default_campaign_cfg())))
    path = os.path.join(GOLDEN, "campaign_summary.json")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} bytes, {time.time() - t0:.1f}s)")

    t0 = time.time()
    doc = jsonw.write(campaign.event_campaign_json(campaign.run_event_campaign(
        campaign.default_event_cfg())))
    path = os.path.join(GOLDEN, "event_summary.json")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} bytes, {time.time() - t0:.1f}s)")

    t0 = time.time()
    doc = jsonw.write(campaign.cog_campaign_json(campaign.run_cog_campaign(
        campaign.default_cog_cfg())))
    path = os.path.join(GOLDEN, "cogsim_summary.json")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} bytes, {time.time() - t0:.1f}s)")

    t0 = time.time()
    doc = jsonw.write(control.control_campaign_json(control.run_control_campaign(
        control.default_control_cfg())))
    path = os.path.join(GOLDEN, "control_summary.json")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} bytes, {time.time() - t0:.1f}s)")

    t0 = time.time()
    doc = jsonw.write(fluid.scale_campaign_json(fluid.run_scale_campaign_with_anchors(
        fluid.default_scale_cfg())))
    path = os.path.join(GOLDEN, "scale_summary.json")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} bytes, {time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
