"""Re-verify every numeric claim the Rust test suite pins, against
the transliterated pipeline.  Run after any engine change; pass
--full to also re-derive the two slow goldens (event ~14 min,
cogsim ~30 s in CPython).

The claims mirror, in order: calibration anchors (netsim/devices/
rdu/workload unit tests), the fabric degenerate limit and fair-share
hand computations (fabric_props), the engine-level fabric properties
(eventsim/cogsim in-file tests), the campaign_golden headlines
including the contention crossover's pinned numbers, and the fluid
tier / surrogate contract (fluid_props) with its scale golden.
"""

import math
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import campaign as cp  # noqa: E402
import cluster as cl  # noqa: E402
import devices  # noqa: E402
import jsonw  # noqa: E402
import rdu  # noqa: E402
import netsim  # noqa: E402
import workload  # noqa: E402
from cogsim import CogSim  # noqa: E402
from eventsim import EventSim, FabricLayer  # noqa: E402
from fabric import FabricEngine, Topology, max_min_rates  # noqa: E402

CHECKS = [0]


def ok(cond, msg):
    CHECKS[0] += 1
    assert cond, msg


def pool():
    return [cl.RduBackend("rdu/pool0", 4, rdu.RDU_CPP_OPT),
            cl.RduBackend("rdu/pool1", 2, rdu.RDU_PYTHON)]


def one_rdu():
    return [cl.RduBackend("rdu/pool0", 4, rdu.RDU_CPP_OPT)]


def ecfg(**kw):
    base = dict(ranks=4, materials=8, samples_per_request=(2, 3), requests_per_burst=6,
                mir_every=0, mir_samples=512, arrival=("synchronized", 0.02, 0.0),
                batching=None, horizon_s=0.2, seed=42)
    base.update(kw)
    return base


def ccfg(**kw):
    base = dict(ranks=4, timesteps=8, compute_s=2e-3, compute_jitter_s=0.0,
                requests_per_step=6, models=8, samples_per_request=(2, 3),
                mir_every=0, mir_samples=512, overlap=0.0, swap_s=0.0,
                residency_slots=4, batching=None, seed=42)
    base.update(kw)
    return base


def fab(ranks, over, n=2):
    return FabricLayer(Topology.pooled(ranks, n, over), list(range(n)), n)


def anchors():
    link = netsim.Link.infiniband_cx6()
    ok(8e-6 <= link.rtt_overhead_s(netsim.payload_bytes(42, 30, 4)) <= 14e-6, "fig15 small")
    ok(abs(link.rtt_overhead_s(netsim.payload_bytes(42, 30, 16384)) / 1.14e-3 - 1) < 0.15,
       "fig15 16k")
    total = netsim.payload_bytes(42, 30, 64)
    ok(abs(2 * link.dir_fixed_s() + total / link.eff_bandwidth
           - link.rtt_overhead_s(total)) < 1e-15, "direction split")
    ok(devices.hermit().param_count == 2866530, "hermit params")
    ok(devices.mir_noln().param_count == 695921, "mir_noln params")
    m = devices.GpuModel(devices.Gpu.a100(), devices.NAIVE_PYTORCH, devices.hermit())
    ok(abs(m.latency_s(1) * 1e3 / 0.65 - 1) < 0.10, "a100 naive @1")
    ok(abs(m.latency_s(32768) * 1e3 / 3.92 - 1) < 0.10, "a100 naive @32k")
    r = rdu.RduModel(devices.hermit(), 4, rdu.RDU_CPP_OPT)
    ok(0.02 < r.latency_best_s(1) * 1e3 < 0.06, "rdu cpp @1")
    w = workload.HydraWorkload(1, 10000, 8, (2, 3), 0)
    ok(20000 <= sum(s for *_, s in w.timestep(0)) <= 30000, "hydra volume")


def fair_share():
    nic = netsim.Link.infiniband_cx6().eff_bandwidth
    t = Topology.pooled(4, 2, 1.0)
    ok(max_min_rates(t.capacities, [t.request_path(0, 0), t.request_path(1, 0)])
       == [nic / 2.0, nic / 2.0], "2 flows NIC bottleneck")
    ok(max_min_rates(t.capacities, [t.request_path(0, 0), t.request_path(1, 0),
                                    t.request_path(2, 1)])
       == [nic / 2.0, nic / 2.0, nic], "3 flows")
    t8 = Topology.pooled(4, 2, 8.0)
    rates = max_min_rates(t8.capacities, [t8.request_path(h, h % 2) for h in range(4)])
    ok(all(abs(r - nic / 16.0) < 1e-6 for r in rates), "4 flows uplink bottleneck")


def degenerate_limit():
    link = netsim.Link.infiniband_cx6()
    topo = Topology.pooled(4, 2, 1.0)
    for batch in (1, 4, 64, 1024, 16384):
        b_in, b_out = netsim.dir_payload_bytes(42, 30, batch)
        eng = FabricEngine(topo)
        elapsed = 0.0
        for b in (b_in, b_out):
            eng.start(elapsed, topo.request_path(0, 1), b)
            t = eng.next_completion_s()
            eng.take_completed(t)
            elapsed = t + topo.dir_fixed_s(1)
        ok(abs(elapsed - link.rtt_overhead_s(netsim.payload_bytes(42, 30, batch))) < 1e-9,
           f"1-flow limit batch {batch}")

    c = ccfg(ranks=1, timesteps=6, requests_per_step=1, models=1)
    legacy = CogSim(one_rdu(), cl.ROUND_ROBIN, c, [0], [0])
    legacy.run_to_completion()
    f = CogSim(one_rdu(), cl.ROUND_ROBIN, c, [0], [0], fab(1, 1.0, 1))
    f.run_to_completion()
    for l, fr in zip(legacy.records, f.records):
        ok(abs(l["complete_s"] - fr["complete_s"]) < 1e-9, "cogsim degenerate complete")
        ok(abs(l["link_s"] - fr["link_s"]) < 1e-9, "cogsim degenerate link")
        ok(abs(fr["contention_s"]) < 1e-9, "cogsim degenerate contention")
    ok(abs(legacy.time_to_solution_s() - f.time_to_solution_s()) < 1e-9, "degenerate TTS")

    ec = ecfg(ranks=1, arrival=("closed_loop", 2e-3), horizon_s=0.05)
    le = EventSim(one_rdu(), cl.ROUND_ROBIN, ec, [0], [0])
    le.run_to_completion()
    fe = EventSim(one_rdu(), cl.ROUND_ROBIN, ec, [0], [0], fab(1, 1.0, 1))
    fe.run_to_completion()
    ok(le.submitted == fe.submitted > 0, "closed loop volume")
    for l, fr in zip(le.records, fe.records):
        ok(abs(l["complete_s"] - fr["complete_s"]) < 1e-9, "eventsim degenerate complete")


def engine_properties():
    sim = EventSim(pool(), cl.LEAST_OUTSTANDING, ecfg(ranks=16, horizon_s=0.045),
                   [0, 1], [0, 1], fab(16, 4.0))
    sim.run_to_completion()
    ok(sim.completed == sim.submitted == 3 * 16 * 6, "fabric conservation")
    s = sim.summary()
    ok(s["mean_contention_s"] > 0, "burst contention")
    ok(s["mean_link_overhead_s"] > s["mean_contention_s"], "contention subset")
    ideal = netsim.Link.infiniband_cx6()
    for r in sim.records:
        floor = ideal.rtt_overhead_s(netsim.payload_bytes(42, 30, r["batch_samples"]))
        ok(r["link_overhead_s"] >= floor - 1e-12, "measured >= uncontended floor")

    for policy, key in ((cl.LEAST_OUTSTANDING, 32), (cl.ROUND_ROBIN, 16)):
        last = (0.0, 0.0, 0.0)
        for over in (1.0, 2.0, 4.0, 8.0):
            sim = EventSim(pool(), policy, ecfg(ranks=key, horizon_s=0.045),
                           [0, 1], [0, 1], fab(key, over))
            sim.run_to_completion()
            n = len(sim.records)
            cur = (sim.summary()["mean_link_overhead_s"],
                   sum(r["complete_s"] for r in sim.records) / n,
                   max(r["complete_s"] for r in sim.records))
            ok(all(c >= l - 1e-12 for c, l in zip(cur, last)),
               f"event monotone r{key} o{over}")
            last = cur

    sim = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(ranks=12, timesteps=5, swap_s=200e-6),
                 [0, 1], [0, 1], fab(12, 4.0))
    sim.run_to_completion()
    for s in sim.steps:
        comp = (s["compute_s"] + s["queue_s"] + s["swap_s"] + s["network_s"]
                + s["service_s"])
        ok(abs(comp - (s["end_s"] - s["start_s"])) < 1e-9, "breakdown sums")
        ok(0 <= s["contention_s"] <= s["network_s"] + 1e-15, "contention subset of net")
    ok(sim.summary()["total_contention_s"] > 0, "cogsim contention")

    last = 0.0
    for over in (1.0, 2.0, 4.0, 8.0):
        s2 = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(ranks=16, timesteps=4),
                    [0, 1], [0, 1], fab(16, over))
        s2.run_to_completion()
        ok(s2.time_to_solution_s() >= last - 1e-12, f"cog TTS monotone o{over}")
        last = s2.time_to_solution_s()

    free = CogSim(pool(), cl.ROUND_ROBIN, ccfg(ranks=8, timesteps=4, swap_s=0.0),
                  [0, 1], [0, 1], fab(8, 2.0))
    free.run_to_completion()
    sw = CogSim(pool(), cl.ROUND_ROBIN, ccfg(ranks=8, timesteps=4, swap_s=2e-3),
                [0, 1], [0, 1], fab(8, 2.0))
    sw.run_to_completion()
    ok(sw.time_to_solution_s() > free.time_to_solution_s(), "swap congestion slows TTS")
    ok(free.swap_time_s == 0.0 and sw.swaps > 0, "swap accounting")
    ok(sw.swap_time_s >= 2e-3 * sw.swaps - 1e-9, "contended swap >= uncontended")


def campaign_headlines():
    cfg = cp.default_cog_cfg()

    def cog(topology, policy, ranks, swap, oversub):
        return cp.run_cog_scenario(topology, policy, ranks, 8, swap, 0.0, oversub,
                                   cfg)["summary"]

    aff = cog("pooled", cl.MODEL_AFFINITY, 4, 2e-3, 1.0)
    rr = cog("pooled", cl.ROUND_ROBIN, 4, 2e-3, 1.0)
    aff0 = cog("pooled", cl.MODEL_AFFINITY, 4, 0.0, 1.0)
    rr0 = cog("pooled", cl.ROUND_ROBIN, 4, 0.0, 1.0)
    ok(aff["time_to_solution_s"] < rr["time_to_solution_s"], "affinity wins TTS")
    ok(aff["swaps"] * 2 < rr["swaps"], "affinity swaps less")
    ok(aff["total_swap_s"] < rr["total_swap_s"], "affinity swap share")
    ok(aff["time_to_solution_s"] / rr["time_to_solution_s"]
       < aff0["time_to_solution_s"] / rr0["time_to_solution_s"], "swap moves the ratio")

    # the contention crossover with its pinned numbers (±2%)
    within = lambda x, t: abs(x / t - 1.0) < 0.02
    for ranks in (4, 32):
        last = 0.0
        for o in (1.0, 2.0, 4.0, 8.0):
            t = cog("pooled", cl.LATENCY_AWARE, ranks, 0.0, o)["time_to_solution_s"]
            ok(t >= last - 1e-12, f"crossover monotone r{ranks} o{o}")
            last = t
    p4 = cog("pooled", cl.LATENCY_AWARE, 4, 0.0, 1.0)["time_to_solution_s"]
    l4 = cog("local", cl.LATENCY_AWARE, 4, 0.0, 1.0)["time_to_solution_s"]
    l32 = cog("local", cl.LATENCY_AWARE, 32, 0.0, 1.0)["time_to_solution_s"]
    relaxed = cog("pooled", cl.LATENCY_AWARE, 32, 0.0, 1.0)
    starved = cog("pooled", cl.LATENCY_AWARE, 32, 0.0, 8.0)
    ok(p4 < l4, "pooled wins at 4 ranks")
    ok(starved["time_to_solution_s"] > l32, "pooled loses at 32 ranks 8:1")
    ok(within(p4, 20.70e-3), f"pinned p4 {p4}")
    ok(within(l4, 21.64e-3) and within(l32, 21.64e-3), f"pinned local {l4} {l32}")
    ok(within(starved["time_to_solution_s"], 53.43e-3), "pinned starved")
    ok(starved["total_contention_s"] > 8.0 * relaxed["total_contention_s"],
       "contention grows ~10x")

    ecfg_ = cp.default_event_cfg()
    bursty = ("synchronized", 0.02, 0.0)
    for pol in (cl.ROUND_ROBIN, cl.LATENCY_AWARE):
        off = cp.run_event_scenario("pooled", pol, bursty, 64, 0.0, 1.0, ecfg_)["summary"]
        on = cp.run_event_scenario("pooled", pol, bursty, 64, 200.0, 1.0, ecfg_)["summary"]
        ok(on["latency"]["p99_s"] < off["latency"]["p99_s"], f"batching wins p99 {pol}")
        ok(on["batches"] < off["batches"] / 4, "fewer batches")
        ok(on["mean_batch_samples"] > 4.0 * off["mean_batch_samples"], "bigger batches")


def mixed_fleet():
    """The fleet axis (rust/tests/scenario_props.rs): mixed GPU+RDU
    pools in all three modes from one knob set, the affinity swap
    bound, and the pinned hybrid-pool-vs-pure-pools headline."""
    within = lambda x, t: abs(x / t - 1.0) < 0.02
    cfg = cp.default_cog_cfg()
    mixed = ("mixed", 4, 2)

    def tts(fleet, ranks):
        return cp.run_cog_scenario("pooled", cl.LATENCY_AWARE, ranks, 8, 0.0, 0.0, 1.0,
                                   cfg, fleet)["summary"]["time_to_solution_s"]

    # the headline: pure RDU < hybrid < pure GPU < starved default
    d32 = tts(cp.DEFAULT_FLEET, 32)
    r32 = tts(("mixed", 0, 6), 32)
    g32 = tts(("mixed", 6, 0), 32)
    h32 = tts(mixed, 32)
    ok(within(d32, 52.99e-3), f"pinned default32 {d32}")
    ok(within(r32, 28.56e-3), f"pinned pure-rdu32 {r32}")
    ok(within(g32, 46.18e-3), f"pinned pure-gpu32 {g32}")
    ok(within(h32, 36.77e-3), f"pinned hybrid32 {h32}")
    ok(r32 < h32 < g32 < d32, "fleet ordering at 32 ranks")
    ok(within(tts(mixed, 4), 18.90e-3), "pinned hybrid4")

    # conservation in all three modes from one config
    a = cp.run_scenario_with_link("pooled", cl.LEAST_OUTSTANDING,
                                  cp.default_campaign_cfg(), netsim.Link.infiniband_cx6(), mixed)
    ok(len(a["backends"]) == 6, "mixed pool size")
    ok(sum(b["samples"] for b in a["backends"])
       == a["hydra"]["samples"] + a["mir"]["samples"], "analytic conservation")
    e = cp.run_event_scenario("pooled", cl.LEAST_OUTSTANDING, ("synchronized", 0.02, 0.0),
                              8, 0.0, 2.0, cp.default_event_cfg(), mixed)["sim"]
    ok(e.submitted == e.completed == 11 * 8 * 6, "event conservation")
    served = {r["backend"] for r in e.records}
    ok(served == set(range(6)), "every mixed-pool member serves")
    c = cp.run_cog_scenario("pooled", cl.LEAST_OUTSTANDING, 8, 8, 0.0, 0.0, 2.0,
                            cfg, mixed)["sim"]
    ok(c.submitted == c.completed == 8 * 8 * 6, "cog conservation")

    # affinity property: stable mapping, bounded distinct models,
    # exactly one swap per model (vs round-robin thrash)
    aff = cp.run_cog_scenario("pooled", cl.MODEL_AFFINITY, 8, 8, 2e-3, 0.0, 1.0,
                              cfg, mixed)["sim"]
    mapping, distinct = {}, {}
    for r in aff.records:
        ok(mapping.setdefault(r["model"], r["backend"]) == r["backend"],
           "affinity mapping stable")
        distinct.setdefault(r["backend"], set()).add(r["model"])
    bound = min(8, 4 * 6)
    ok(all(len(ms) <= bound for ms in distinct.values()), "distinct-model bound")
    ok(len(mapping) == 8 and aff.swaps == 8, "one swap per pinned model")
    rr = cp.run_cog_scenario("pooled", cl.ROUND_ROBIN, 8, 8, 2e-3, 0.0, 1.0,
                             cfg, mixed)["sim"]
    ok(rr.swaps > 2 * aff.swaps, "round-robin thrashes")

    # fleet anchor: mixed{0g2r} is byte-for-byte the default pool
    b0 = cp.build_fleet("pooled", 4, netsim.Link.infiniband_cx6())[0]
    b1 = cp.build_fleet("pooled", 4, netsim.Link.infiniband_cx6(), ("mixed", 0, 2))[0]
    prof = devices.hermit()
    for x, y in zip(b0, b1):
        ok(x.name == y.name and x.execute_s(prof, 64) == y.execute_s(prof, 64),
           "mixed{0g2r} == default pool")


def control_plane():
    import control as ctrl
    from eventsim import latency_dist, rank_rngs

    # ---- spec parsing round-trips (harness::scenario tests)
    ok(ctrl.parse_control("static") == ctrl.static_spec(), "static parses")
    ok(ctrl.parse_control("") is None, "empty spec rejected")
    s = ctrl.parse_control("leave:0@30000+join:0@60000+auto:2:1-4:100:2000")
    ok(s is not None and s["key"] == "leave:0@30000+join:0@60000+auto:2:1-4:100:2000",
       "compound key round-trips")
    ok(s["trace"] == [(0.03, ("leave", 0)), (0.06, ("join", 0))], "trace parses")
    ok(s["autoscaler"] == {"initial": 2, "min_active": 1, "max_active": 4,
                           "low_s": 100.0 * 1e-6, "high_s": 2000.0 * 1e-6},
       "autoscaler parses")
    ok(ctrl.parse_control("degrade:0.25@6000+restore@20000")["trace"]
       == [(0.006, ("degrade", 0.25)), (0.02, ("restore",))], "degrade/restore parse")
    ok(ctrl.parse_control("rankfail:1@10000")["trace"] == [(0.01, ("rankfail", 1))],
       "rankfail parses")
    for bad in ["leave:0", "leave@5", "degrade:0@5", "degrade:-1@5", "leave:0@-5",
                "auto:2:1-4:100", "auto:2:1-4:100:2000+auto:2:1-4:100:2000",
                "frob:1@5", "leave:0@nan"]:
        ok(ctrl.parse_control(bad) is None, f"{bad!r} rejected")
    ok(not ctrl.is_static(ctrl.parse_control("leave:0@5")), "leave is not static")
    ok(ctrl.is_static(ctrl.parse_control("static")), "static is static")

    # ---- quantile fix: never-completed requests (non-finite
    # latencies) are excluded from the distribution, not counted as
    # zero-latency entries
    base = [1e-3, 2e-3, 3e-3, 4e-3]
    d0 = latency_dist(base)
    d1 = latency_dist(base + [math.nan, math.inf])
    ok(d0 == d1, "quantiles exclude never-completed")
    ok(d1["p50_s"] > 0.0 and d1["count"] == 4, "no zero-latency ghosts")

    # ---- differential: an armed-but-empty control plane is
    # byte-identical to the legacy static run, every workload kind
    for arrival in [("synchronized", 0.02, 0.0), ("poisson", 800.0),
                    ("closed_loop", 2e-3)]:
        a = EventSim(pool(), cl.LEAST_OUTSTANDING, ecfg(arrival=arrival, horizon_s=0.05),
                     [0, 1], [0, 1], None)
        a.run_to_completion()
        b = EventSim(pool(), cl.LEAST_OUTSTANDING, ecfg(arrival=arrival, horizon_s=0.05),
                     [0, 1], [0, 1], None)
        b.with_control([])
        b.run_to_completion()
        ok(jsonw.write(cp.event_summary_json(a.summary()))
           == jsonw.write(cp.event_summary_json(b.summary())),
           f"empty trace differential ({arrival[0]})")
    a = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(), [0, 1], [0, 1], None)
    a.run_to_completion()
    b = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(), [0, 1], [0, 1], None)
    b.with_control([], None)
    b.run_to_completion()
    ok(jsonw.write(cp.cog_summary_json(a.summary()))
       == jsonw.write(cp.cog_summary_json(b.summary())),
       "empty trace differential (cog)")

    # ---- failure injection: backend loss mid-run, orphans
    # re-dispatched exactly once, retries excluded from latencies
    sim = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(), [0, 1], [0, 1], None)
    sim.with_control([(2.2e-3, ("leave", 0))], None)
    sim.run_to_completion()
    s = sim.summary()
    ok(sim.orphaned() > 0, "leave orphans in-flight work")
    ok(sim.orphaned() == sim.retries(), "orphans re-dispatched exactly once")
    ok(s["failed"] == 0 and s["requests"] == s["submitted"],
       "survivors absorb the loss")
    ok(len(sim.steps) == 8 and sim.in_flight() == 0, "run completes")
    ok(not sim.backend_active(0) and sim.backend_active(1), "membership tracked")
    ok(all(r["backend"] != 0 or not r["retried"] for r in sim.records),
       "retries land on survivors")
    retried = [r for r in sim.records if r["retried"]]
    ok(len(retried) == sim.retries(), "one record per retried request")
    ok(s["latency"]["count"] == s["requests"] - len(retried),
       "first-attempt latencies only")
    ok(all(math.isfinite(r["complete_s"]) for r in sim.records),
       "every record eventually completes")
    # same loss against the fabric path (flows cancelled, not leaked)
    fsim = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(), [0, 1], [0, 1], fab(4, 2.0))
    fsim.with_control([(2.2e-3, ("leave", 0))], None)
    fsim.run_to_completion()
    ok(fsim.orphaned() == fsim.retries() and fsim.in_flight() == 0,
       "fabric-path loss conserves")
    ok(fsim.core.fabric.engine.active() == 0, "no leaked flows")
    # losing the whole tier parks work until a join revives it
    dead = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(timesteps=2), [0, 1], [0, 1], None)
    dead.with_control([(2.2e-3, ("leave", 0)), (2.2e-3, ("leave", 1)),
                       (5e-3, ("join", 0))], None)
    dead.run_to_completion()
    ok(dead.summary()["failed"] == 0 and len(dead.steps) == 2,
       "join flushes parked work")
    # rank checkpoint/restart: replay finishes all steps, waste counted
    rsim = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(), [0, 1], [0, 1], None)
    rsim.with_control([(2.2e-3, ("rankfail", 1))], None)
    rsim.run_to_completion()
    ok(rsim.rank_restarts == 1 and len(rsim.steps) == 8, "rankfail replays the step")
    ok(rsim.summary()["submitted"] > 8 * 4 * 6, "replay re-submits the lost burst")
    ok(rsim.time_to_solution_s() > a.time_to_solution_s(), "restart costs time")

    # ---- chaos: randomized seeded traces conserve, produce finite
    # summaries, and rerun byte-identically
    def chaos_trace(seed, horizon_s, n_backends, n_ranks):
        rng = rank_rngs(seed, 1)[0]
        trace = []
        for _ in range(rng.range(3, 8)):
            at = rng.uniform(0.0, horizon_s)
            kind = rng.below(5)
            if kind == 0:
                trace.append((at, ("leave", rng.below(n_backends))))
            elif kind == 1:
                trace.append((at, ("join", rng.below(n_backends))))
            elif kind == 2:
                trace.append((at, ("degrade", 0.1 + 0.8 * rng.uniform(0.0, 1.0))))
            elif kind == 3:
                trace.append((at, ("restore",)))
            else:
                trace.append((at, ("rankfail", rng.below(n_ranks))))
        return trace

    def finite_doc(v):
        if isinstance(v, float):
            return math.isfinite(v)
        if isinstance(v, dict):
            return all(finite_doc(x) for x in v.values())
        if isinstance(v, list):
            return all(finite_doc(x) for x in v)
        return True

    for seed in [1, 7, 99]:
        trace = chaos_trace(seed, 20e-3, 2, 4)
        docs = []
        for _ in range(2):
            sim = CogSim(pool(), cl.LEAST_OUTSTANDING, ccfg(timesteps=4),
                         [0, 1], [0, 1], fab(4, 2.0))
            sim.with_control(trace, None)
            sim.run_to_completion()
            s = sim.summary()
            fin = sum(1 for r in sim.records if math.isfinite(r["complete_s"]))
            ok(s["submitted"] == fin + sim.parked() + sim.batcher_pending(),
               f"cog chaos conserves (seed {seed})")
            ok(s["retries"] == sim.orphaned(), f"cog chaos retries once (seed {seed})")
            docs.append(jsonw.write(cp.cog_summary_json(s)))
            ok(finite_doc(cp.cog_summary_json(s)), f"cog chaos finite (seed {seed})")
        ok(docs[0] == docs[1], f"cog chaos rerun identical (seed {seed})")

        trace = chaos_trace(seed + 1000, 40e-3, 2, 4)
        docs = []
        for _ in range(2):
            sim = EventSim(pool(), cl.LEAST_OUTSTANDING,
                           ecfg(arrival=("poisson", 800.0), horizon_s=0.05),
                           [0, 1], [0, 1], None)
            sim.with_control(trace)
            sim.run_to_completion()
            s = sim.summary()
            ok(s["submitted"] == s["requests"] + s["failed"] + sim.core.batcher_pending(),
               f"event chaos conserves (seed {seed})")
            ok(s["failed"] == sim.parked(), f"event chaos failures parked (seed {seed})")
            docs.append(jsonw.write(cp.event_summary_json(s)))
            ok(finite_doc(cp.event_summary_json(s)), f"event chaos finite (seed {seed})")
        ok(docs[0] == docs[1], f"event chaos rerun identical (seed {seed})")

    # ---- the control campaign headline (golden-pinned)
    r = ctrl.run_control_campaign(ctrl.default_control_cfg())
    ll = ctrl.loss_ratio(r, "local")
    lp = ctrl.loss_ratio(r, "pooled")
    ok(1.0 < lp < ll, "pooled absorbs a one-backend loss more gracefully")
    ok(ctrl.cell(r, "local/leave")["summary"]["retries"] > 0, "loss cells orphan work")
    ok(ctrl.cell(r, "pooled/leave")["summary"]["retries"] > 0, "pooled loss orphans work")
    ok(ctrl.cell(r, "pooled/rankfail")["summary"]["rank_restarts"] == 1,
       "rankfail cell restarts once")
    auto = ctrl.autoscaler_factor(r)
    ok(auto <= ctrl.AUTOSCALER_BOUND, "autoscaler holds the TTS bound")
    ok(ctrl.cell(r, "pooled/auto")["summary"]["mean_active_backends"]
       < ctrl.cell(r, "pooled/static")["summary"]["mean_active_backends"],
       "autoscaler sheds idle capacity")
    for c in r["cells"]:
        ok(c["summary"]["failed"] == 0, f"{c['label']} completes all work")
    golden = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "rust", "tests", "golden")
    doc = jsonw.write(ctrl.control_campaign_json(r))
    with open(os.path.join(golden, "control_summary.json")) as f:
        ok(f.read() == doc, "control golden reproduces")


def fluid_tier():
    """The fluid tier and fitted surrogate (rust/tests/fluid_props.rs):
    the contention-free collapse, oversub/ranks monotonicity, the
    surrogate's exact/affine/clamp interpolation contract, and the
    committed scale golden with its crossover trajectory and its
    event-engine anchor cells (the golden pins the anchors, so the 64-
    and 256-rank coupled cells run here in the fast path too).  The
    slow grid-wide cross-validations (the 15 %/5 % pinned bounds over
    the whole campaign) ride behind --full with the other
    cogsim-scale work."""
    import fluid
    import surrogate as surro

    def fcfg(**kw):
        base = dict(timesteps=8, compute_s=2e-3, requests_per_step=6,
                    samples_per_request=(2, 3), residency_slots=4,
                    window_us=0.0, max_batch=256)
        base.update(kw)
        return base

    # collapse: one rank/model/request, fixed batch — every steady-state
    # correction vanishes and the step is compute + backend latency
    c = fcfg(samples_per_request=(3, 3), requests_per_step=1)
    s = fluid.solve_cell("local", cl.ROUND_ROBIN, 1, 1, 0.0, 0.0, 1.0, c)
    be = cl.GpuBackend("gpu/local", devices.Gpu.a100(), devices.TRT_CUDA_GRAPHS)
    step = max(2e-3, 2e-3 + be.latency_s(devices.hermit(), 3))
    ok(abs(s["time_to_solution_s"] - 8 * step) <= 1e-9, "fluid collapse")
    ok(s["total_queue_s"] == 0.0 and s["total_swap_s"] == 0.0,
       "collapse has no corrections")

    # TTS never improves when the fabric starves or the machine grows
    for policy in (cl.ROUND_ROBIN, cl.LEAST_OUTSTANDING, cl.LATENCY_AWARE,
                   cl.MODEL_AFFINITY):
        for swap in (0.0, 2e-3):
            last = 0.0
            for over in (1.0, 2.0, 3.0, 4.0, 6.0, 8.0):
                t = fluid.solve_cell("pooled", policy, 32, 8, swap, 0.0, over,
                                     fcfg())["time_to_solution_s"]
                ok(t >= last - 1e-12, f"fluid oversub monotone {policy} o{over}")
                last = t
    for policy in (cl.ROUND_ROBIN, cl.LEAST_OUTSTANDING, cl.LATENCY_AWARE):
        last = 0.0
        for ranks in (4, 8, 16, 32, 64, 256):
            t = fluid.solve_cell("pooled", policy, ranks, 8, 2e-3, 0.0, 4.0,
                                 fcfg())["time_to_solution_s"]
            ok(t >= last - 1e-12, f"fluid ranks monotone {policy} r{ranks}")
            last = t

    # surrogate contract on a synthetic affine grid: exact on training
    # nodes and affine interiors, clamped outside the hull, incomplete
    # tables dropped rather than extrapolated from holes
    rows = []
    for ranks in (4.0, 32.0):
        for over in (1.0, 4.0):
            rows.append({"topology": "pooled", "policy": "round_robin",
                         "models": 8, "overlap": 0.0, "ranks": ranks,
                         "oversub": over, "swap_us": 0.0, "window_us": 0.0,
                         "tts_s": 1.0 + 0.5 * ranks + 2.0 * over,
                         "p99_s": 0.1 * ranks})
    sur = surro.Surrogate.fit(rows)
    ok(len(sur.tables) == 1, "surrogate fits one table")
    tts, p99 = sur.predict("pooled", "round_robin", 8, 0.0, 4.0, 1.0, 0.0, 0.0)
    ok(abs(tts - 5.0) < 1e-12 and abs(p99 - 0.4) < 1e-12, "surrogate exact on node")
    tts, _ = sur.predict("pooled", "round_robin", 8, 0.0, 18.0, 2.5, 0.0, 0.0)
    ok(abs(tts - (1.0 + 0.5 * 18.0 + 2.0 * 2.5)) < 1e-12, "surrogate affine interior")
    ok(sur.predict("pooled", "round_robin", 8, 0.0, 1.0, 0.5, 0.0, 0.0)
       == sur.predict("pooled", "round_robin", 8, 0.0, 4.0, 1.0, 0.0, 0.0),
       "surrogate clamps outside the hull")
    ok(len(surro.Surrogate.fit(rows[:-1]).tables) == 0, "incomplete table dropped")

    # the scale campaign: the crossover trajectory the golden pins
    r = fluid.run_scale_campaign_with_anchors(fluid.default_scale_cfg())
    x = {row["ranks"]: row["crossover_pool"] for row in r["rows"]}
    ok(x[64] == 256 and x[256] == 512, "crossover trajectory (small machines)")
    ok(all(x[n] is None for n in (1024, 4096, 16384)),
       "node-local wins at leadership scale")
    # the event-engine anchors: swap-free pooled cells at 64/256 ranks
    # must hold the pinned fluid-vs-event bound beyond the 32-rank grid
    ok([a["ranks"] for a in r["anchors"]] == [64, 256], "anchor cells present")
    for a in r["anchors"]:
        err = a["fluid_tts_s"] / a["event_tts_s"] - 1.0
        ok(abs(err) <= fluid.ANCHOR_TTS_BOUND,
           f"scale anchor r{a['ranks']}: {err:+.2%} within the 15% contract")
        ok(abs(err) <= 0.02,
           f"scale anchor r{a['ranks']}: {err:+.2%} near the measured ~0.1%")
    golden = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "rust", "tests", "golden")
    doc = jsonw.write(fluid.scale_campaign_json(r))
    with open(os.path.join(golden, "scale_summary.json")) as f:
        ok(f.read() == doc, "scale golden reproduces")

    if "--full" in sys.argv:
        # the pinned cross-validation bounds against the event engine:
        # fluid ≤ 15 % TTS on the uncongested half of the default grid
        # (measured worst case 12.9 %), surrogate exact on training
        cfg = cp.default_cog_cfg()
        res = cp.run_cog_campaign(cfg)
        checked = 0
        for s in res["scenarios"]:
            if not (s["swap_s"] == 0.0 or s["oversub"] <= 2.0):
                continue
            f_ = fluid.solve_cell(s["topology"], s["policy"], s["ranks"],
                                  s["models"], s["swap_s"], s["overlap"],
                                  s["oversub"], cfg)
            err = f_["time_to_solution_s"] / s["summary"]["time_to_solution_s"] - 1.0
            ok(abs(err) <= 0.15,
               f"fluid bound {s['topology']}/{s['policy']}/r{s['ranks']}"
               f"/o{s['oversub']}/sw{s['swap_s']}: {err:+.1%}")
            checked += 1
        ok(checked >= 40, "uncongested half covers the grid")
        sur = surro.fit_cog_campaign(res)
        for s in res["scenarios"]:
            tts, p99 = sur.predict(s["topology"], s["policy"], s["models"],
                                   s["overlap"], s["ranks"], s["oversub"],
                                   s["swap_s"] * 1e6, cfg["window_us"])
            ok(abs(tts / s["summary"]["time_to_solution_s"] - 1.0) <= 1e-12,
               "surrogate exact on training cell")
            ok(abs(p99 / s["summary"]["latency"]["p99_s"] - 1.0) <= 1e-12,
               "surrogate exact p99 on training cell")


def golden_stability():
    golden = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "rust", "tests", "golden")
    doc = jsonw.write(cp.campaign_json(cp.run_campaign(cp.default_campaign_cfg())))
    with open(os.path.join(golden, "campaign_summary.json")) as f:
        ok(f.read() == doc, "analytic golden reproduces")
    if "--full" in sys.argv:
        doc = jsonw.write(cp.event_campaign_json(cp.run_event_campaign(
            cp.default_event_cfg())))
        with open(os.path.join(golden, "event_summary.json")) as f:
            ok(f.read() == doc, "event golden reproduces")
        doc = jsonw.write(cp.cog_campaign_json(cp.run_cog_campaign(
            cp.default_cog_cfg())))
        with open(os.path.join(golden, "cogsim_summary.json")) as f:
            ok(f.read() == doc, "cogsim golden reproduces")


def main():
    t0 = time.time()
    for phase in (anchors, fair_share, degenerate_limit, engine_properties,
                  campaign_headlines, mixed_fleet, control_plane, fluid_tier,
                  golden_stability):
        t1 = time.time()
        phase()
        print(f"{phase.__name__}: OK ({time.time() - t1:.1f}s)")
    print(f"\n{CHECKS[0]} checks passed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
