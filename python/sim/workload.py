"""workload transliteration: Hydra + MIR request generators."""

import math

from rng import Rng
from rustfloat import MASK64


def material_model(material):
    return f"hermit/mat{material}"


class HydraWorkload:
    def __init__(self, ranks, zones_per_rank, materials, inferences_per_zone, seed):
        self.ranks = ranks
        self.zones_per_rank = zones_per_rank
        self.materials = materials
        self.inferences_per_zone = inferences_per_zone
        self.seed = seed

    def timestep(self, t):
        rng = Rng(self.seed ^ ((t * 0x9E3779B9) & MASK64))
        reqs = []
        for rank in range(self.ranks):
            zones_of_material = [0] * self.materials
            for _ in range(self.zones_per_rank):
                zones_of_material[rng.below(self.materials)] += 1
            for mat, zones in enumerate(zones_of_material):
                if zones == 0:
                    continue
                lo, hi = self.inferences_per_zone
                total = 0
                for _ in range(zones):
                    total += rng.range(lo, hi)
                reqs.append((t, rank, material_model(mat), total))
        return reqs


class MirWorkload:
    def __init__(self, ranks, base_zones, variation, seed):
        self.ranks = ranks
        self.base_zones = base_zones
        self.variation = variation
        self.seed = seed

    def timestep(self, t):
        rng = Rng(self.seed ^ ((t * 0x517CC1B7) & MASK64))
        phase = float(t) / 50.0 * (2.0 * math.pi)
        out = []
        for rank in range(self.ranks):
            drift = 1.0 + self.variation * math.sin(phase)
            jitter = max(1.0 + 0.1 * rng.normal(), 0.2)
            zones = int(max(float(self.base_zones) * drift * jitter, 1.0))
            out.append((t, rank, "mir", zones))
        return out
