"""Rust-exact float helpers: `f64::round`, `Duration` nanosecond
conversions, and the `{}` Display formatting util::json relies on."""

import math
from fractions import Fraction

MASK64 = (1 << 64) - 1

F64_MIN_POSITIVE = 2.2250738585072014e-308


def rust_round(x: float) -> float:
    """f64::round: nearest integer, ties away from zero (exact)."""
    f = math.floor(x)
    diff = x - f  # exact: |x - floor(x)| <= 1 and same scale
    if diff > 0.5:
        return float(f + 1)
    if diff < 0.5:
        return float(f)
    # tie: away from zero
    return float(f + 1) if x > 0.0 else float(f)


def dur_from_secs_f64(x: float) -> int:
    """Duration::from_secs_f64 as integer nanoseconds: nearest ns,
    ties to even, computed exactly from the binary value."""
    assert x >= 0.0 and math.isfinite(x)
    ns = Fraction(x) * 10**9
    return round(ns)  # Fraction.__round__ is ties-to-even


def dur_as_secs_f64(ns: int) -> float:
    """Duration::as_secs_f64: secs as f64 + nanos as f64 / 1e9."""
    secs, nanos = divmod(ns, 10**9)
    return float(secs) + float(nanos) / 1e9


def _positional(s: str) -> str:
    """Convert a repr like '2e-06' / '1.5e+16' to positional digits
    (Rust's `{}` Display never uses exponent notation)."""
    if "e" not in s and "E" not in s:
        return s
    mant, _, exp = s.partition("e" if "e" in s else "E")
    e = int(exp)
    neg = mant.startswith("-")
    if neg:
        mant = mant[1:]
    if "." in mant:
        int_part, frac_part = mant.split(".")
    else:
        int_part, frac_part = mant, ""
    digits = int_part + frac_part
    point = len(int_part) + e
    if point <= 0:
        out = "0." + "0" * (-point) + digits
    elif point >= len(digits):
        out = digits + "0" * (point - len(digits))
    else:
        out = digits[:point] + "." + digits[point:]
    out = out.rstrip(".") if out.endswith(".") else out
    return ("-" if neg else "") + out


def fmt_f64(x: float) -> str:
    """util::json's number rendering: integers < 1e15 as i64, the
    rest via Rust `{}` Display (shortest round-trip, positional)."""
    if x == math.trunc(x) and abs(x) < 1e15:
        return str(int(x))
    return _positional(repr(x))
