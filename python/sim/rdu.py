"""rdu transliteration: the RDU dataflow model."""

import math

RDU_PYTHON = "Python"
RDU_PYTHON_OPT = "PythonOptimized"
RDU_CPP_OPT = "CppOptimized"

TILE_SRAM_BYTES = 8.0 * 1024.0 * 1024.0
PREFERRED_MB_SPEEDUP = 0.88


def _host_us(api):
    return {RDU_PYTHON: 75.0, RDU_PYTHON_OPT: 70.0, RDU_CPP_OPT: 18.0}[api]


def _placement_speedup(api):
    return 1.0 if api == RDU_PYTHON else 1.55


def _per_micro_us(api):
    return 1.2 if api == RDU_CPP_OPT else 0.55


class RduModel:
    def __init__(self, profile, tiles, api):
        assert 1 <= tiles <= 4
        self.profile = profile
        self.tiles = tiles
        self.api = api
        self.preferred_mb = False

    def depth(self):
        per_tile = 3 if self.profile.name.startswith("mir") else 2
        return per_tile * self.tiles

    def t_sample_s(self):
        full_rdu_rate = 9.9e6 if self.profile.name == "hermit" else 0.148e6
        rate = full_rdu_rate * float(self.tiles) / 4.0 * _placement_speedup(self.api) / 1.55
        return 1.0 / rate

    def stream_bytes_per_sample(self):
        if self.profile.name.startswith("mir"):
            return 2.0 * 48.0 * 48.0 * 16.0
        return 2.0 * 2050.0

    def spill_factor(self, micro):
        bytes_ = float(micro) * self.stream_bytes_per_sample()
        sram = TILE_SRAM_BYTES * float(self.tiles)
        if bytes_ <= sram:
            return 1.0
        return 1.0 + 1.05 * min(bytes_ / sram - 1.0, 6.0)

    def t_min_s(self):
        return 0.45e-6 + _per_micro_us(self.api) * 1e-6

    def stage_s(self, micro):
        return self.t_min_s() + float(micro) * self.t_sample_s() * self.spill_factor(micro)

    def fill_stage_s(self, micro):
        return self.t_min_s() + float(micro) * self.t_sample_s()

    def latency_s(self, mini, micro):
        n_micro = float(-(-mini // micro))  # div_ceil
        lat = (_host_us(self.api) * 1e-6
               + float(self.depth() - 1) * self.fill_stage_s(micro)
               + n_micro * self.stage_s(micro))
        if self.preferred_mb and micro % 6 == 0 and mini % micro == 0:
            lat *= PREFERRED_MB_SPEEDUP
        return lat

    @staticmethod
    def micro_candidates(mini, preferred):
        v = []
        m = 1
        while m <= mini:
            v.append(m)
            m *= 2
        if preferred:
            m = 6
            while m <= mini:
                if mini % m == 0:
                    v.append(m)
                m += 6
            v = sorted(set(v))
        return v

    def best_micro(self, mini):
        best = (1, math.inf)
        for micro in self.micro_candidates(mini, self.preferred_mb):
            l = self.latency_s(mini, micro)
            if l < best[1]:
                best = (micro, l)
        return best[0]

    def latency_best_s(self, mini):
        return self.latency_s(mini, self.best_micro(mini))
