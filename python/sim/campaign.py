"""harness::campaign transliteration: all three modes + JSON emit."""

import math

import devices
import stats
from cluster import (ALL_POLICIES, Cluster, GpuBackend, RduBackend, LATENCY_AWARE,
                     ROUND_ROBIN)
from cogsim import CogSim
from eventsim import EventSim, FabricLayer
from fabric import Topology as NetTopology
from netsim import Link
from rustfloat import F64_MIN_POSITIVE, rust_round
from workload import HydraWorkload, MirWorkload

TOPOLOGIES = ["local", "pooled", "hybrid"]


def pays_the_link(topology):
    return topology != "local"


def oversubs_for(topology, oversubs):
    return list(oversubs) if pays_the_link(topology) else [1.0]


# --------------------------------------------------------- fleets
#
# Fleet axis (mirrors harness::scenario::Fleet): "default" is the
# legacy 4-tile-C++ + 2-tile-Python RDU pair; ("mixed", G, R) is a
# heterogeneous pool of G remote A100/TRT-CG members followed by R RDU
# tile groups alternating the default pair's shapes.

DEFAULT_FLEET = "default"


def fleet_pool_size(fleet):
    if fleet == DEFAULT_FLEET:
        return 2
    _, gpus, rdus = fleet
    return gpus + rdus


def pool_members(fleet, pool_link):
    import rdu
    if fleet == DEFAULT_FLEET:
        return [
            RduBackend("rdu/pool0", 4, rdu.RDU_CPP_OPT, pool_link.clone()),
            RduBackend("rdu/pool1", 2, rdu.RDU_PYTHON, pool_link.clone()),
        ]
    _, gpus, rdus = fleet
    assert gpus + rdus >= 1
    members = [GpuBackend(f"gpu/pool{i}", devices.Gpu.a100(), devices.TRT_CUDA_GRAPHS,
                          pool_link.clone())
               for i in range(gpus)]
    for j in range(rdus):
        tiles, api = (4, rdu.RDU_CPP_OPT) if j % 2 == 0 else (2, rdu.RDU_PYTHON)
        members.append(RduBackend(f"rdu/pool{gpus + j}", tiles, api, pool_link.clone()))
    return members


def build_fleet(topology, ranks, pool_link, fleet=DEFAULT_FLEET):
    def local_gpu(r):
        return GpuBackend(f"gpu/rank{r}", devices.Gpu.a100(), devices.TRT_CUDA_GRAPHS)

    if topology == "local":
        backends = [local_gpu(r) for r in range(ranks)]
        allidx = list(range(len(backends)))
        return backends, (allidx, list(allidx))
    if topology == "pooled":
        backends = pool_members(fleet, pool_link)
        allidx = list(range(len(backends)))
        return backends, (allidx, list(allidx))
    # hybrid
    backends = [local_gpu(r) for r in range(ranks)]
    gpu_idx = list(range(len(backends)))
    backends.extend(pool_members(fleet, pool_link))
    pool_idx = list(range(len(gpu_idx), len(backends)))
    return backends, (pool_idx, gpu_idx)  # (hermit, mir)


def build_fabric_spec(topology, ranks, oversub, fleet=DEFAULT_FLEET):
    pool = fleet_pool_size(fleet)
    if topology == "local":
        return None
    if topology == "pooled":
        return (NetTopology.pooled(ranks, pool, oversub), list(range(pool)))
    return (NetTopology.hybrid(ranks, pool, oversub),
            list(range(ranks)) + list(range(ranks, ranks + pool)))


# -------------------------------------------------- analytic mode


def default_campaign_cfg():
    return {
        "ranks": 4, "zones_per_rank": 200, "materials": 8, "timesteps": 12,
        "step_period_s": 0.02, "mir_base_zones": 1024, "fabric_oversubs": [1.0],
        "seed": 42,
    }


def derated_link(link, oversub):
    import math
    l = link.clone()
    if math.isfinite(l.eff_bandwidth):
        l.eff_bandwidth = l.eff_bandwidth / oversub
    return l


def run_scenario_with_link(topology, policy, cfg, pool_link, fleet=DEFAULT_FLEET):
    backends, (hermit_tier, mir_tier) = build_fleet(topology, cfg["ranks"], pool_link, fleet)
    cluster = Cluster(backends, policy)
    hydra = HydraWorkload(cfg["ranks"], cfg["zones_per_rank"], cfg["materials"],
                          (2, 3), cfg["seed"])
    mir = MirWorkload(cfg["ranks"], cfg["mir_base_zones"], 0.4, cfg["seed"] ^ 0x5EED)
    hermit_profile = devices.hermit()
    mir_profile = devices.mir_noln()

    hydra_lat, hydra_link, hydra_samples = [], [], 0
    mir_lat, mir_link, mir_samples = [], [], 0
    for t in range(cfg["timesteps"]):
        cluster.advance_to(float(t) * cfg["step_period_s"])
        for (_, _, model, samples) in hydra.timestep(t):
            _, _, latency_s, link_overhead_s = cluster.submit_among(
                hermit_tier, model, hermit_profile, samples)
            hydra_lat.append(latency_s)
            hydra_link.append(link_overhead_s)
            hydra_samples += samples
        for (_, _, model, samples) in mir.timestep(t):
            _, _, latency_s, link_overhead_s = cluster.submit_among(
                mir_tier, model, mir_profile, samples)
            mir_lat.append(latency_s)
            mir_link.append(link_overhead_s)
            mir_samples += samples

    makespan_s = cluster.makespan_s()

    def workload_summary(lat, link, samples):
        return {
            "requests": len(lat), "samples": samples, "mean_s": stats.mean(lat),
            "p50_s": stats.percentile(lat, 50.0), "p95_s": stats.percentile(lat, 95.0),
            "p99_s": stats.percentile(lat, 99.0), "mean_link_overhead_s": stats.mean(link),
            "samples_per_s": (float(samples) / makespan_s if makespan_s > 0.0 else 0.0),
        }

    reports = []
    for b, st in zip(cluster.backends, cluster.stats):
        reports.append({"name": b.name, "requests": st[0], "samples": st[1],
                        "busy_s": st[2], "queue_s": b.queue_s()})
    return {
        "topology": topology, "policy": policy, "oversub": 1.0,
        "hydra": workload_summary(hydra_lat, hydra_link, hydra_samples),
        "mir": workload_summary(mir_lat, mir_link, mir_samples),
        "makespan_s": makespan_s, "backends": reports,
    }


def run_scenario_at(topology, policy, oversub, cfg):
    link = derated_link(Link.infiniband_cx6(), oversub)
    s = run_scenario_with_link(topology, policy, cfg, link)
    s["oversub"] = oversub
    return s


def run_campaign(cfg):
    scenarios = []
    for topology in TOPOLOGIES:
        for policy in ALL_POLICIES:
            for oversub in oversubs_for(topology, cfg["fabric_oversubs"]):
                scenarios.append(run_scenario_at(topology, policy, oversub, cfg))
    return {"config": cfg, "scenarios": scenarios}


# ----------------------------------------------------- event mode


def default_event_cfg():
    return {
        "topologies": ["local", "pooled"],
        "policies": [ROUND_ROBIN, LATENCY_AWARE],
        "rank_counts": [4, 64],
        "arrivals": [("synchronized", 0.02, 0.0), ("poisson", 800.0),
                     ("closed_loop", 2e-3)],
        "windows_us": [0.0, 200.0],
        "max_batch": 256,
        "materials": 8,
        "samples_per_request": (2, 3),
        "requests_per_burst": 6,
        "mir_every": 0,
        "mir_samples": 512,
        "fabric_oversubs": [1.0, 4.0],
        "horizon_s": 0.2,
        "seed": 42,
    }


def run_event_scenario(topology, policy, arrival, ranks, window_us, oversub, cfg,
                       fleet=DEFAULT_FLEET):
    backends, (hermit_tier, mir_tier) = build_fleet(topology, ranks, Link.infiniband_cx6(),
                                                    fleet)
    sim_cfg = {
        "ranks": ranks, "materials": cfg["materials"],
        "samples_per_request": cfg["samples_per_request"],
        "requests_per_burst": cfg["requests_per_burst"],
        "mir_every": cfg["mir_every"], "mir_samples": cfg["mir_samples"],
        "arrival": arrival,
        "batching": ((window_us * 1e-6, cfg["max_batch"]) if window_us > 0.0 else None),
        "horizon_s": cfg["horizon_s"], "seed": cfg["seed"],
    }
    spec = build_fabric_spec(topology, ranks, oversub, fleet)
    fabric = FabricLayer(spec[0], spec[1], len(backends)) if spec else None
    sim = EventSim(backends, policy, sim_cfg, hermit_tier, mir_tier, fabric)
    sim.run_to_completion()
    return {
        "topology": topology, "policy": policy, "arrival": arrival, "ranks": ranks,
        "window_us": window_us, "oversub": oversub, "summary": sim.summary(),
        "sim": sim,
    }


def run_event_campaign(cfg):
    scenarios = []
    for topology in cfg["topologies"]:
        for policy in cfg["policies"]:
            for ranks in cfg["rank_counts"]:
                for arrival in cfg["arrivals"]:
                    for window_us in cfg["windows_us"]:
                        for oversub in oversubs_for(topology, cfg["fabric_oversubs"]):
                            scenarios.append(run_event_scenario(
                                topology, policy, arrival, ranks, window_us, oversub, cfg))
    return {"config": cfg, "scenarios": scenarios}


# ---------------------------------------------------- cogsim mode


def default_cog_cfg():
    return {
        "topologies": ["local", "pooled"],
        "policies": list(ALL_POLICIES),
        "rank_counts": [4, 32],
        "models_per_rank": [8],
        "swap_costs_s": [0.0, 2e-3],
        "overlaps": [0.0],
        "timesteps": 8,
        "compute_s": 2e-3,
        "requests_per_step": 6,
        "samples_per_request": (2, 3),
        "mir_every": 0,
        "mir_samples": 512,
        "residency_slots": 4,
        "window_us": 0.0,
        "max_batch": 256,
        "fabric_oversubs": [1.0, 2.0, 4.0, 8.0],
        "seed": 42,
    }


def run_cog_scenario(topology, policy, ranks, models, swap_s, overlap, oversub, cfg,
                     fleet=DEFAULT_FLEET):
    backends, (hermit_tier, mir_tier) = build_fleet(topology, ranks, Link.infiniband_cx6(),
                                                    fleet)
    sim_cfg = {
        "ranks": ranks, "timesteps": cfg["timesteps"], "compute_s": cfg["compute_s"],
        "compute_jitter_s": 0.0, "requests_per_step": cfg["requests_per_step"],
        "models": models, "samples_per_request": cfg["samples_per_request"],
        "mir_every": cfg["mir_every"], "mir_samples": cfg["mir_samples"],
        "overlap": overlap, "swap_s": swap_s,
        "residency_slots": cfg["residency_slots"],
        "batching": ((cfg["window_us"] * 1e-6, cfg["max_batch"])
                     if cfg["window_us"] > 0.0 else None),
        "seed": cfg["seed"],
    }
    spec = build_fabric_spec(topology, ranks, oversub, fleet)
    fabric = FabricLayer(spec[0], spec[1], len(backends)) if spec else None
    sim = CogSim(backends, policy, sim_cfg, hermit_tier, mir_tier, fabric)
    sim.run_to_completion()
    return {
        "topology": topology, "policy": policy, "ranks": ranks, "models": models,
        "swap_s": swap_s, "overlap": overlap, "oversub": oversub,
        "summary": sim.summary(), "sim": sim,
    }


def run_cog_campaign(cfg):
    scenarios = []
    for topology in cfg["topologies"]:
        for policy in cfg["policies"]:
            for ranks in cfg["rank_counts"]:
                for models in cfg["models_per_rank"]:
                    for swap_s in cfg["swap_costs_s"]:
                        for overlap in cfg["overlaps"]:
                            for oversub in oversubs_for(topology, cfg["fabric_oversubs"]):
                                scenarios.append(run_cog_scenario(
                                    topology, policy, ranks, models, swap_s, overlap,
                                    oversub, cfg))
    return {"config": cfg, "scenarios": scenarios}


# ------------------------------------------------------------- JSON


def us(seconds):
    # non-finite -> 0 (mirrors report.rs): empty-population quantiles
    # are NaN and a golden field must never carry NaN
    if not math.isfinite(seconds):
        return 0.0
    return rust_round(seconds * 1e9) / 1e3


def fixed3(v):
    if not math.isfinite(v):
        return 0.0
    return rust_round(v * 1e3) / 1e3


def config_json(cfg):
    return {
        "ranks": float(cfg["ranks"]),
        "zones_per_rank": float(cfg["zones_per_rank"]),
        "materials": float(cfg["materials"]),
        "timesteps": float(cfg["timesteps"]),
        "step_period_us": us(cfg["step_period_s"]),
        "mir_base_zones": float(cfg["mir_base_zones"]),
        "fabric_oversubs": [fixed3(v) for v in cfg["fabric_oversubs"]],
        "seed": float(cfg["seed"]),
    }


def workload_json(w):
    return {
        "requests": float(w["requests"]),
        "samples": float(w["samples"]),
        "mean_us": us(w["mean_s"]),
        "p50_us": us(w["p50_s"]),
        "p95_us": us(w["p95_s"]),
        "p99_us": us(w["p99_s"]),
        "mean_link_overhead_us": us(w["mean_link_overhead_s"]),
        "samples_per_s": fixed3(w["samples_per_s"]),
    }


def scenario_json(s):
    makespan = max(s["makespan_s"], F64_MIN_POSITIVE)
    return {
        "topology": s["topology"],
        "policy": s["policy"],
        "oversub": fixed3(s["oversub"]),
        "hydra": workload_json(s["hydra"]),
        "mir": workload_json(s["mir"]),
        "makespan_us": us(s["makespan_s"]),
        "backends": [
            {
                "name": b["name"],
                "requests": float(b["requests"]),
                "samples": float(b["samples"]),
                "busy_us": us(b["busy_s"]),
                "utilization": rust_round(b["busy_s"] / makespan * 1e6) / 1e6,
            }
            for b in s["backends"]
        ],
    }


def campaign_json(result):
    return {
        "config": config_json(result["config"]),
        "scenarios": [scenario_json(s) for s in result["scenarios"]],
    }


def arrival_json(a):
    if a[0] == "synchronized":
        return {"kind": "synchronized", "period_us": us(a[1]), "jitter_us": us(a[2])}
    if a[0] == "poisson":
        return {"kind": "poisson", "rate_per_rank": fixed3(a[1])}
    return {"kind": "closed_loop", "think_us": us(a[1])}


def event_config_json(cfg):
    return {
        "topologies": list(cfg["topologies"]),
        "policies": list(cfg["policies"]),
        "rank_counts": [float(r) for r in cfg["rank_counts"]],
        "arrivals": [arrival_json(a) for a in cfg["arrivals"]],
        "windows_us": [fixed3(w) for w in cfg["windows_us"]],
        "fabric_oversubs": [fixed3(v) for v in cfg["fabric_oversubs"]],
        "max_batch": float(cfg["max_batch"]),
        "materials": float(cfg["materials"]),
        "samples_per_request": [float(cfg["samples_per_request"][0]),
                                float(cfg["samples_per_request"][1])],
        "requests_per_burst": float(cfg["requests_per_burst"]),
        "mir_every": float(cfg["mir_every"]),
        "mir_samples": float(cfg["mir_samples"]),
        "horizon_us": us(cfg["horizon_s"]),
        "seed": float(cfg["seed"]),
    }


def event_summary_json(s):
    lat = s["latency"]
    return {
        "requests": float(s["requests"]),
        "samples": float(s["samples"]),
        "batches": float(s["batches"]),
        "mean_batch_samples": fixed3(s["mean_batch_samples"]),
        "mean_us": us(lat["mean_s"]),
        "p50_us": us(lat["p50_s"]),
        "p90_us": us(lat["p90_s"]),
        "p99_us": us(lat["p99_s"]),
        "p999_us": us(lat["p999_s"]),
        "max_us": us(lat["max_s"]),
        "mean_link_overhead_us": us(s["mean_link_overhead_s"]),
        "mean_contention_us": us(s["mean_contention_s"]),
        "samples_per_s": fixed3(s["samples_per_s"]),
        "makespan_us": us(s["makespan_s"]),
        "slowdown_max": fixed3(s["slowdown_max"]),
        "histogram": [
            {"le_us": le_us, "count": float(c)}
            for le_us, c in lat["histogram"]
            if c > 0
        ],
        "overflow": float(lat["overflow"]),
    }


def event_scenario_json(s):
    return {
        "topology": s["topology"],
        "policy": s["policy"],
        "arrival": s["arrival"][0],
        "ranks": float(s["ranks"]),
        "window_us": fixed3(s["window_us"]),
        "oversub": fixed3(s["oversub"]),
        "summary": event_summary_json(s["summary"]),
    }


def event_campaign_json(result):
    return {
        "config": event_config_json(result["config"]),
        "scenarios": [event_scenario_json(s) for s in result["scenarios"]],
    }


def cog_config_json(cfg):
    return {
        "topologies": list(cfg["topologies"]),
        "policies": list(cfg["policies"]),
        "rank_counts": [float(r) for r in cfg["rank_counts"]],
        "models_per_rank": [float(m) for m in cfg["models_per_rank"]],
        "swap_costs_us": [us(s) for s in cfg["swap_costs_s"]],
        "overlaps": [fixed3(o) for o in cfg["overlaps"]],
        "fabric_oversubs": [fixed3(v) for v in cfg["fabric_oversubs"]],
        "timesteps": float(cfg["timesteps"]),
        "compute_us": us(cfg["compute_s"]),
        "requests_per_step": float(cfg["requests_per_step"]),
        "samples_per_request": [float(cfg["samples_per_request"][0]),
                                float(cfg["samples_per_request"][1])],
        "mir_every": float(cfg["mir_every"]),
        "mir_samples": float(cfg["mir_samples"]),
        "residency_slots": float(cfg["residency_slots"]),
        "window_us": fixed3(cfg["window_us"]),
        "max_batch": float(cfg["max_batch"]),
        "seed": float(cfg["seed"]),
    }


def cog_summary_json(s):
    lat = s["latency"]
    return {
        "ranks": float(s["ranks"]),
        "timesteps": float(s["timesteps"]),
        "requests": float(s["requests"]),
        "samples": float(s["samples"]),
        "batches": float(s["batches"]),
        "time_to_solution_us": us(s["time_to_solution_s"]),
        "mean_step_us": us(s["mean_step_s"]),
        "total_compute_us": us(s["total_compute_s"]),
        "total_queue_us": us(s["total_queue_s"]),
        "total_swap_us": us(s["total_swap_s"]),
        "total_network_us": us(s["total_network_s"]),
        "total_contention_us": us(s["total_contention_s"]),
        "total_service_us": us(s["total_service_s"]),
        "swaps": float(s["swaps"]),
        "swap_time_us": us(s["swap_time_s"]),
        "max_spread_us": us(s["max_spread_s"]),
        "request_p50_us": us(lat["p50_s"]),
        "request_p99_us": us(lat["p99_s"]),
        "straggler_counts": [float(c) for c in s["straggler_counts"]],
        "steps": [
            {
                "step": float(st["step"]),
                "duration_us": us(st["end_s"] - st["start_s"]),
                "straggler": float(st["straggler"]),
                "compute_us": us(st["compute_s"]),
                "queue_us": us(st["queue_s"]),
                "swap_us": us(st["swap_s"]),
                "network_us": us(st["network_s"]),
                "contention_us": us(st["contention_s"]),
                "service_us": us(st["service_s"]),
                "spread_us": us(st["spread_s"]),
            }
            for st in s["steps"]
        ],
    }


def cog_scenario_json(s):
    return {
        "topology": s["topology"],
        "policy": s["policy"],
        "ranks": float(s["ranks"]),
        "models": float(s["models"]),
        "swap_us": us(s["swap_s"]),
        "overlap": fixed3(s["overlap"]),
        "oversub": fixed3(s["oversub"]),
        "summary": cog_summary_json(s["summary"]),
    }


def cog_campaign_json(result):
    return {
        "config": cog_config_json(result["config"]),
        "scenarios": [cog_scenario_json(s) for s in result["scenarios"]],
    }
