"""util::stats transliteration (mean, percentile)."""


def mean(xs):
    if not xs:
        return 0.0
    # iter().sum::<f64>() is sequential left-to-right addition
    total = 0.0
    for x in xs:
        total += x
    return total / float(len(xs))


def percentile(xs, p):
    # empty population -> NaN (mirrors util::stats): no observations,
    # no quantile — the JSON writers render non-finite values as 0
    if not xs:
        return float("nan")
    s = sorted(xs)
    rank = (p / 100.0) * float(len(s) - 1)
    import math

    lo = math.floor(rank)
    hi = math.ceil(rank)
    lo_i, hi_i = int(lo), int(hi)
    if lo_i == hi_i:
        return s[lo_i]
    frac = rank - float(lo_i)
    return s[lo_i] * (1.0 - frac) + s[hi_i] * frac
