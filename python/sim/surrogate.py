"""harness::surrogate transliteration: fitted grid interpolator.

Mirrors rust/src/surrogate/mod.rs op-for-op.  The surrogate is fitted
on event-engine (cog) grid results: cells sharing a categorical key
(topology, fleet, policy, models, overlap, control) form a dense table
over the numeric axes (ranks, oversub, swap_us, window_us), and
predictions are clamped multilinear interpolations over that table —
exact on training nodes, nearest-cell (clamp) outside the hull.

Coordinates are raw linear values: TTS is near-affine in ranks (batch
count scales with ranks at fixed pool) and in oversubscription (the
swap-transfer cost scales with it), so linear beats log coordinates on
held-out interior cells by an order of magnitude.
"""


def _axis_bracket(axis, x):
    """Clamped bracketing: (lo_index, fraction in [0, 1])."""
    n = len(axis)
    if n == 1 or x <= axis[0]:
        return 0, 0.0
    if x >= axis[n - 1]:
        return n - 2, 1.0
    i = 0
    while x > axis[i + 1]:
        i += 1
    return i, (x - axis[i]) / (axis[i + 1] - axis[i])


class Table4:
    """Dense 4-D table over (ranks, oversub, swap_us, window_us)."""

    def __init__(self, ranks, oversubs, swaps, windows):
        self.ranks = ranks
        self.oversubs = oversubs
        self.swaps = swaps
        self.windows = windows
        n = len(ranks) * len(oversubs) * len(swaps) * len(windows)
        self.tts = [None] * n
        self.p99 = [None] * n

    def index(self, ir, io, isw, iw):
        return ((ir * len(self.oversubs) + io) * len(self.swaps) + isw) \
            * len(self.windows) + iw

    def complete(self):
        return all(v is not None for v in self.tts)

    def interpolate(self, grid, ranks, oversub, swap_us, window_us):
        ir, fr = _axis_bracket(self.ranks, ranks)
        io, fo = _axis_bracket(self.oversubs, oversub)
        isw, fs = _axis_bracket(self.swaps, swap_us)
        iw, fw = _axis_bracket(self.windows, window_us)

        def corner(dr, do, ds, dw):
            jr = min(ir + dr, len(self.ranks) - 1)
            jo = min(io + do, len(self.oversubs) - 1)
            js = min(isw + ds, len(self.swaps) - 1)
            jw = min(iw + dw, len(self.windows) - 1)
            return grid[self.index(jr, jo, js, jw)]

        total = 0.0
        for dr in (0, 1):
            wr = (1.0 - fr) if dr == 0 else fr
            if wr == 0.0:
                continue
            for do in (0, 1):
                wo = (1.0 - fo) if do == 0 else fo
                if wo == 0.0:
                    continue
                for ds in (0, 1):
                    ws = (1.0 - fs) if ds == 0 else fs
                    if ws == 0.0:
                        continue
                    for dw in (0, 1):
                        ww = (1.0 - fw) if dw == 0 else fw
                        if ww == 0.0:
                            continue
                        total += wr * wo * ws * ww * corner(dr, do, ds, dw)
        return total


class Surrogate:
    """Fitted interpolator over event-engine grid results."""

    def __init__(self):
        self.tables = {}

    @staticmethod
    def fit(rows):
        """rows: iterables of dicts with keys topology, policy, models,
        overlap, ranks, oversub, swap_us, window_us, tts_s, p99_s (plus
        optional fleet/control keys folded into the categorical key).
        Incomplete tables (missing grid corners) are dropped."""
        by_key = {}
        for row in rows:
            key = (row["topology"], row.get("fleet", "default"), row["policy"],
                   row["models"], row["overlap"], row.get("control", "static"))
            by_key.setdefault(key, []).append(row)

        sur = Surrogate()
        for key, cells in by_key.items():
            ranks = sorted({c["ranks"] for c in cells})
            oversubs = sorted({c["oversub"] for c in cells})
            swaps = sorted({c["swap_us"] for c in cells})
            windows = sorted({c["window_us"] for c in cells})
            table = Table4([float(r) for r in ranks], oversubs, swaps, windows)
            for c in cells:
                idx = table.index(ranks.index(c["ranks"]),
                                  oversubs.index(c["oversub"]),
                                  swaps.index(c["swap_us"]),
                                  windows.index(c["window_us"]))
                table.tts[idx] = c["tts_s"]
                table.p99[idx] = c["p99_s"]
            if table.complete():
                sur.tables[key] = table
        return sur

    def predict(self, topology, policy, models, overlap, ranks, oversub,
                swap_us, window_us, fleet="default", control="static"):
        """(tts_s, p99_s) or None when no complete table covers the key."""
        table = self.tables.get((topology, fleet, policy, models, overlap, control))
        if table is None:
            return None
        tts = table.interpolate(table.tts, float(ranks), oversub, swap_us, window_us)
        p99 = table.interpolate(table.p99, float(ranks), oversub, swap_us, window_us)
        return tts, p99


def fit_cog_campaign(result):
    """Fit a surrogate from a run_cog_campaign result dict."""
    rows = []
    for s in result["scenarios"]:
        rows.append({
            "topology": s["topology"], "policy": s["policy"],
            "models": s["models"], "overlap": s["overlap"],
            "ranks": s["ranks"], "oversub": s["oversub"],
            "swap_us": s["swap_s"] * 1e6,
            "window_us": result["config"]["window_us"],
            "tts_s": s["summary"]["time_to_solution_s"],
            "p99_s": s["summary"]["latency"]["p99_s"],
        })
    return Surrogate.fit(rows)
