"""devices transliteration: ModelProfile builders + the GPU model."""

import math

BATCH_SAT = 32768.0

HERMIT_WIDTHS = [42, 19, 17, 13, 10, 12, 16, 24, 32, 48, 64, 128, 256, 512, 1024, 2050,
                 27, 27, 27, 27, 27, 30]


class ModelProfile:
    __slots__ = (
        "name", "param_count", "flops_per_sample", "weight_bytes",
        "activation_bytes_per_sample", "n_layers", "kernels_per_layer_naive",
        "has_layernorm", "input_elems", "output_elems", "util_factor", "sat_exp_scale",
    )


def hermit():
    params = 0
    flops = 0.0
    act_bytes = 0.0
    for d_in, d_out in zip(HERMIT_WIDTHS, HERMIT_WIDTHS[1:]):
        params += d_in * d_out + d_out
        flops += 2.0 * float(d_in * d_out)
        act_bytes += 2.0 * 2.0 * float(d_out)
    p = ModelProfile()
    p.name = "hermit"
    p.param_count = params
    p.flops_per_sample = flops
    p.weight_bytes = 2.0 * float(params)
    p.activation_bytes_per_sample = act_bytes
    p.n_layers = len(HERMIT_WIDTHS) - 1
    p.kernels_per_layer_naive = 3.0
    p.has_layernorm = False
    p.input_elems = 42
    p.output_elems = 30
    p.util_factor = 1.0
    p.sat_exp_scale = 1.0
    return p


def mir():
    channels = [1, 16, 32, 64, 128]
    sizes = [48, 24, 12, 6]
    params = 0
    flops = 0.0
    act_bytes = 0.0
    for i in range(4):
        cin, cout = channels[i], channels[i + 1]
        hw = sizes[i] * sizes[i]
        params += 9 * cin * cout + cout
        flops += 2.0 * float(hw * 9 * cin * cout)
        act_bytes += 2.0 * 2.0 * float(hw * cout)
        params += 2 * cout
    for d_in, d_out in [(4608, 64), (64, 64), (64, 4608)]:
        params += d_in * d_out + d_out
        flops += 2.0 * float(d_in * d_out)
        act_bytes += 2.0 * 2.0 * float(d_out)
    dec_sizes = [6, 6, 12, 24]
    for i, layer in enumerate(reversed(range(4))):
        cin, cout = channels[layer + 1], channels[layer]
        stride = 1 if layer == 3 else 2
        out_side = dec_sizes[i] * stride
        hw = out_side * out_side
        params += cout
        flops += 2.0 * float(hw * 9 * cin * cout)
        act_bytes += 2.0 * 2.0 * float(hw * cout)
    p = ModelProfile()
    p.name = "mir"
    p.param_count = params
    p.flops_per_sample = flops
    p.weight_bytes = 2.0 * float(params)
    p.activation_bytes_per_sample = act_bytes
    p.n_layers = 15
    p.kernels_per_layer_naive = 4.0
    p.has_layernorm = True
    p.input_elems = 48 * 48
    p.output_elems = 48 * 48
    p.util_factor = 0.065
    p.sat_exp_scale = 0.065
    return p


def mir_noln():
    p = mir()
    p.name = "mir_noln"
    p.has_layernorm = False
    ln_params = sum(2 * c for c in [16, 32, 64, 128])
    p.param_count -= ln_params
    p.weight_bytes = 2.0 * float(p.param_count)
    p.n_layers = 11
    return p


# ------------------------------------------------------------- APIs

NAIVE_PYTORCH = "NaivePyTorch"
TENSOR_RT = "TensorRt"
CUDA_GRAPHS = "CudaGraphs"
TRT_CUDA_GRAPHS = "TrtCudaGraphs"
CPP_TENSOR_RT = "CppTensorRt"

FUSED_EFF_BONUS = 2.22


def api_host_launches(api, p):
    layers = float(p.n_layers)
    if api == NAIVE_PYTORCH:
        return layers * p.kernels_per_layer_naive
    if api in (TENSOR_RT, CPP_TENSOR_RT):
        return layers
    return 2.0  # CudaGraphs / TrtCudaGraphs


def api_device_kernels(api, p):
    layers = float(p.n_layers)
    if api in (NAIVE_PYTORCH, CUDA_GRAPHS):
        return layers * p.kernels_per_layer_naive
    return layers


def api_base_overhead_us(api):
    return {
        NAIVE_PYTORCH: 30.0,
        TENSOR_RT: 40.0,
        CUDA_GRAPHS: 45.0,
        TRT_CUDA_GRAPHS: 70.0,
        CPP_TENSOR_RT: 10.0,
    }[api]


def api_fused(api):
    return api in (TENSOR_RT, TRT_CUDA_GRAPHS, CPP_TENSOR_RT)


def api_layernorm_penalty(api, p):
    if p.has_layernorm and api in (TENSOR_RT, TRT_CUDA_GRAPHS, CPP_TENSOR_RT):
        return 2.2
    return 1.0


class Gpu:
    __slots__ = ("name", "peak_half_tflops", "mem_bw_gbps", "launch_us", "kernel_min_us",
                 "eff_sat", "sat_exponent", "tdp_w", "transistors_b", "plateau")

    def __init__(self, name, peak, bw, launch, kmin, eff_sat, sat_exp, tdp, trans, plateau):
        self.name = name
        self.peak_half_tflops = peak
        self.mem_bw_gbps = bw
        self.launch_us = launch
        self.kernel_min_us = kmin
        self.eff_sat = eff_sat
        self.sat_exponent = sat_exp
        self.tdp_w = tdp
        self.transistors_b = trans
        self.plateau = plateau

    @staticmethod
    def a100():
        return Gpu("A100", 312.0, 1555.0, 8.0, 1.5, 0.183, 0.30, 250.0, 54.2, None)


class GpuModel:
    def __init__(self, gpu, api, profile):
        self.gpu = gpu
        self.api = api
        self.profile = profile

    def host_overhead_s(self):
        return (api_host_launches(self.api, self.profile) * self.gpu.launch_us
                + api_base_overhead_us(self.api)) * 1e-6

    def utilisation(self, batch):
        b = min(float(batch), BATCH_SAT)
        ramp = math.pow(b / BATCH_SAT, self.gpu.sat_exponent * self.profile.sat_exp_scale)
        eff = self.gpu.eff_sat * self.profile.util_factor * ramp
        if api_fused(self.api) and not self.profile.has_layernorm:
            eff *= FUSED_EFF_BONUS
        if self.gpu.plateau is not None:
            threshold, penalty = self.gpu.plateau
            if batch >= threshold:
                eff *= penalty
        return eff

    def device_time_s(self, batch):
        b = float(batch)
        flops = self.profile.flops_per_sample * b * api_layernorm_penalty(self.api, self.profile)
        compute = flops / (self.gpu.peak_half_tflops * 1e12 * self.utilisation(batch))
        act = self.profile.activation_bytes_per_sample * b
        bytes_ = self.profile.weight_bytes + (0.15 * act if api_fused(self.api) else act)
        memory = bytes_ / (self.gpu.mem_bw_gbps * 1e9)
        floor = api_device_kernels(self.api, self.profile) * self.gpu.kernel_min_us * 1e-6
        return max(compute, memory, floor)

    def latency_s(self, batch):
        return self.host_overhead_s() + self.device_time_s(batch)
