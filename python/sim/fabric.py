"""fabric transliteration: Topology, max-min fair share, FabricEngine."""

import math

from netsim import Link

INF = math.inf
DONE_BYTES = 1e-6


class Topology:
    def __init__(self):
        self.link = None
        self.oversubscription = 1.0
        self.capacities = []
        # As-built capacities: the restore point for degrade events.
        self.base_capacities = []
        self.capacity_scale = 1.0
        self.hosts = 0
        self.accel_ports = []  # None | (tx, rx)
        self.host_tx = []
        self.host_rx = []
        self.host_up = None
        self.host_down = None
        self.accel_up = None
        self.accel_down = None

    @staticmethod
    def node_local(n_nodes):
        t = Topology()
        t.link = Link.local()
        t.hosts = n_nodes
        t.accel_ports = [None] * n_nodes
        return t

    @staticmethod
    def pooled(n_hosts, n_accels, oversubscription, link=None):
        return Topology._build(n_hosts, 0, n_accels, oversubscription,
                               link if link is not None else Link.infiniband_cx6())

    @staticmethod
    def hybrid(n_hosts, n_pool, oversubscription):
        return Topology._build(n_hosts, n_hosts, n_pool, oversubscription,
                               Link.infiniband_cx6())

    @staticmethod
    def _build(n_hosts, n_local_accels, n_pool, oversubscription, link):
        assert n_hosts >= 1 and n_pool >= 1
        assert oversubscription >= 1.0 and math.isfinite(oversubscription)
        nic = link.eff_bandwidth
        assert nic > 0.0 and math.isfinite(nic)
        t = Topology()
        t.link = link
        t.oversubscription = oversubscription
        t.hosts = n_hosts

        def push(cap):
            t.capacities.append(cap)
            return len(t.capacities) - 1

        t.host_tx = [push(nic) for _ in range(n_hosts)]
        t.host_rx = [push(nic) for _ in range(n_hosts)]
        t.host_up = push(float(n_hosts) * nic / oversubscription)
        t.host_down = push(float(n_hosts) * nic / oversubscription)
        t.accel_up = push(float(n_pool) * nic / oversubscription)
        t.accel_down = push(float(n_pool) * nic / oversubscription)
        t.accel_ports = [None] * n_local_accels
        for _ in range(n_pool):
            tx = push(nic)
            rx = push(nic)
            t.accel_ports.append((tx, rx))
        t.base_capacities = list(t.capacities)
        return t

    def set_capacity_scale(self, factor):
        # Degrade (or restore) the whole fabric: every directed link's
        # capacity becomes factor x its as-built value.  factor = 1.0
        # restores the as-built capacities exactly (recomputed from
        # the base, so repeated cycles cannot accumulate drift).
        assert factor > 0.0 and math.isfinite(factor), \
            f"capacity scale must be a positive finite factor ({factor})"
        self.capacity_scale = factor
        self.capacities = [base if factor == 1.0 else base * factor
                           for base in self.base_capacities]

    def accels(self):
        return len(self.accel_ports)

    def is_pooled(self, accel):
        return self.accel_ports[accel] is not None

    def dir_fixed_s(self, accel):
        return self.link.dir_fixed_s() if self.accel_ports[accel] is not None else 0.0

    def request_path(self, host, accel):
        port = self.accel_ports[accel]
        if port is None:
            return []
        return [self.host_tx[host], self.host_up, self.accel_down, port[1]]

    def response_path(self, host, accel):
        port = self.accel_ports[accel]
        if port is None:
            return []
        return [port[0], self.accel_up, self.host_down, self.host_rx[host]]

    def swap_path(self, accel):
        port = self.accel_ports[accel]
        if port is None:
            return []
        return [self.accel_down, port[1]]


def _usable(capacities, l):
    # in range with strictly positive capacity; NaN compares False
    return l < len(capacities) and capacities[l] > 0.0


def max_min_rates(capacities, flows):
    n = len(flows)
    rates = [0.0] * n
    frozen = [False] * n
    remaining = list(capacities)
    users = [0] * len(capacities)

    for f, path in enumerate(flows):
        if any(not _usable(capacities, l) for l in path):
            # guarded degenerate path: zero rate, never a user
            frozen[f] = True
        elif not path or all(math.isinf(capacities[l]) for l in path):
            rates[f] = INF
            frozen[f] = True
        else:
            for l in path:
                users[l] += 1

    left = sum(1 for fz in frozen if not fz)
    while left > 0:
        bottleneck = None
        for l, cap in enumerate(remaining):
            if users[l] == 0 or math.isinf(cap):
                continue
            share = cap / float(users[l])
            if bottleneck is None or share < bottleneck[0]:
                bottleneck = (share, l)
        if bottleneck is None:
            for f in range(n):
                if not frozen[f]:
                    rates[f] = INF
                    frozen[f] = True
            break
        share, link = bottleneck
        for f in range(n):
            if frozen[f] or link not in flows[f]:
                continue
            rates[f] = share
            frozen[f] = True
            left -= 1
            for l in flows[f]:
                if math.isfinite(remaining[l]):
                    remaining[l] = max(remaining[l] - share, 0.0)
                users[l] -= 1
    return rates


class FabricEngine:
    def __init__(self, topo):
        self.topo = topo
        # id -> [path, remaining, rate, constrained]; ids monotone
        self.flows = {}
        self.next_id = 0
        self.now_s = 0.0
        self.constrained = 0

    def active(self):
        return len(self.flows)

    def start(self, now_s, path, bytes_):
        assert bytes_ >= 0.0 and math.isfinite(bytes_)
        self.advance_to(now_s)
        fid = self.next_id
        self.next_id += 1
        caps = self.topo.capacities
        # a free-path flow (empty path, or infinite capacity everywhere
        # it goes) rates at infinity without a re-solve: it never
        # counts as a link user, so other flows' shares are untouched
        free = all(l < len(caps) and math.isinf(caps[l]) for l in path)
        self.flows[fid] = [path, bytes_, INF if free else 0.0, not free]
        if free:
            return fid
        self.constrained += 1
        self._recompute()
        return fid

    def advance_to(self, t_s):
        dt = t_s - self.now_s
        if dt > 0.0:
            for f in self.flows.values():
                if math.isinf(f[2]):
                    f[1] = 0.0
                else:
                    f[1] = max(f[1] - f[2] * dt, 0.0)
        self.now_s = max(self.now_s, t_s)

    def _recompute(self):
        paths = [f[0] for f in self.flows.values()]
        rates = max_min_rates(self.topo.capacities, paths)
        for f, r in zip(self.flows.values(), rates):
            f[2] = r

    @staticmethod
    def _eta(f):
        if f[1] <= DONE_BYTES or math.isinf(f[2]):
            return 0.0
        return f[1] / f[2]

    def next_completion_s(self):
        # stalled guarded flows (0 rate) never finish: skip their
        # infinite ETA rather than arm an infinite wake-up
        times = [self.now_s + self._eta(f) for f in self.flows.values()]
        times = [t for t in times if math.isfinite(t)]
        if not times:
            return None
        return min(times)

    def set_capacity_scale(self, now_s, factor):
        # Degrade (or restore) the fabric mid-run: credit every active
        # flow its progress up to now_s at the *old* rates, scale the
        # link capacities, then re-solve over what is left.
        self.advance_to(now_s)
        self.topo.set_capacity_scale(factor)
        if self.constrained > 0:
            self._recompute()

    def cancel(self, now_s, fid):
        # Cancel an active flow (control plane: its destination
        # backend left the fleet).  Progress is credited first, so
        # survivors keep exactly the bytes they moved.
        self.advance_to(now_s)
        f = self.flows.pop(fid, None)
        if f is None:
            return False
        if f[3]:
            self.constrained -= 1
            self._recompute()
        return True

    def take_completed(self, now_s):
        self.advance_to(now_s)
        done = [fid for fid, f in self.flows.items()
                if f[1] <= DONE_BYTES or math.isinf(f[2])]
        constrained_left = 0
        for fid in done:
            if self.flows.pop(fid)[3]:
                constrained_left += 1
        self.constrained -= constrained_left
        # free flows never held link capacity: their departure cannot
        # change anyone's rate, so only re-solve for constrained exits
        if constrained_left:
            self._recompute()
        return done
