"""eventsim::cogsim transliteration: the coupled CogSim engine
driving the simcore Pipeline.

The engine keeps only workload logic — the bulk-synchronous timestep
barrier, per-rank state, and record keeping; every dispatch/batch/
residency/fabric/service decision lives in simcore.Pipeline."""

import math

from equeue import CLASS_ARRIVAL, EventQueue
from eventsim import latency_dist, rank_rngs
from simcore import Pipeline
from workload import material_model


class CogSim:
    def __init__(self, backends, policy, cfg, hermit_tier, mir_tier, fabric=None):
        # cfg keys: ranks, timesteps, compute_s, compute_jitter_s,
        # requests_per_step, models, samples_per_request, mir_every,
        # mir_samples, overlap, swap_s, residency_slots,
        # batching (None | (window_s, max_batch)), seed
        self.cfg = cfg
        self.core = Pipeline(backends, policy, hermit_tier, mir_tier,
                             cfg["batching"],
                             (cfg["residency_slots"], cfg["swap_s"]), fabric)
        self.events = EventQueue()
        self.rngs = rank_rngs(cfg["seed"], cfg["ranks"])
        self.ranks = [self._idle_rank() for _ in range(cfg["ranks"])]
        self.step_start_s = 0.0
        self.current_step = 0
        self.finished_ranks = 0
        # what the pipeline cannot know: [step, emit_s, record];
        # rank/model/samples live in core.req_meta, id-aligned
        self.pending = []
        self.records = []
        self.rec0_of_token = []  # transit token -> first record index
        self.steps = []
        self.events_processed = 0
        self.events.push_class(0.0, CLASS_ARRIVAL, ("step_start", 0))

    @staticmethod
    def _idle_rank():
        return {"compute_end_s": 0.0, "emit_s": 0.0, "outstanding": 0,
                "compute_done": False, "finished": False, "finish_s": 0.0,
                "last_record": None}

    # counters live on the pipeline
    @property
    def clock_s(self):
        return self.core.clock_s

    @property
    def submitted(self):
        return self.core.submitted

    @property
    def dispatched(self):
        return self.core.dispatched_n

    @property
    def completed(self):
        return self.core.completed_n

    @property
    def batches(self):
        return self.core.batches

    @property
    def swaps(self):
        return self.core.swaps

    @property
    def swap_time_s(self):
        return self.core.swap_time_s

    def batcher_pending(self):
        return self.core.batcher_pending()

    # ------------------------------------------------------ run loop

    def _pump(self):
        popped = self.events.pop()
        if popped is None:
            return False
        t, event = popped
        self.events_processed += 1
        self.core.advance_to(t)
        self._handle(event)
        return True

    def run_to_completion(self):
        while self._pump():
            pass

    def _handle(self, event):
        kind = event[0]
        if kind == "step_start":
            self._on_step_start(event[1])
        elif kind == "arrival":
            self._on_request(event[1], event[2], event[3])
        elif kind == "compute_done":
            self._on_compute_done(event[1])
        else:
            self.core.handle(event)
            self._apply_effects()

    # ------------------------------------------------- timestep loop

    def _on_step_start(self, step):
        self.step_start_s = self.clock_s
        self.current_step = step
        self.finished_ranks = 0
        lo, hi = self.cfg["samples_per_request"]
        for rank in range(self.cfg["ranks"]):
            if self.cfg["compute_jitter_s"] > 0.0:
                jitter = self.rngs[rank].uniform(0.0, self.cfg["compute_jitter_s"])
            else:
                jitter = 0.0
            compute = self.cfg["compute_s"] + jitter
            emit_s = self.clock_s + (1.0 - self.cfg["overlap"]) * compute
            compute_end_s = self.clock_s + compute
            outstanding = 0
            for _ in range(self.cfg["requests_per_step"]):
                model = material_model(self.rngs[rank].below(self.cfg["models"]))
                samples = self.rngs[rank].range(lo, hi)
                self.events.push_class(emit_s, CLASS_ARRIVAL,
                                       ("arrival", rank, model, samples))
                outstanding += 1
            if self.cfg["mir_every"] > 0 and step % self.cfg["mir_every"] == 0:
                self.events.push_class(emit_s, CLASS_ARRIVAL,
                                       ("arrival", rank, "mir", self.cfg["mir_samples"]))
                outstanding += 1
            self.ranks[rank] = {
                "compute_end_s": compute_end_s, "emit_s": emit_s,
                "outstanding": outstanding, "compute_done": False,
                "finished": False, "finish_s": 0.0, "last_record": None,
            }
            self.events.push_class(compute_end_s, CLASS_ARRIVAL, ("compute_done", rank))

    def _on_compute_done(self, rank):
        self.ranks[rank]["compute_done"] = True
        self._try_finish(rank)

    def _try_finish(self, rank):
        st = self.ranks[rank]
        if st["finished"] or not st["compute_done"] or st["outstanding"] > 0:
            return
        st["finished"] = True
        st["finish_s"] = self.clock_s
        self.finished_ranks += 1
        if self.finished_ranks == self.cfg["ranks"]:
            self._end_step()

    def _end_step(self):
        start = self.step_start_s
        end = self.clock_s
        step = self.current_step
        straggler = 0
        for r in range(1, self.cfg["ranks"]):
            if self.ranks[r]["finish_s"] > self.ranks[straggler]["finish_s"]:
                straggler = r
        min_finish = math.inf
        for r in self.ranks:
            min_finish = min(min_finish, r["finish_s"])
        st = self.ranks[straggler]
        if st["last_record"] is None:
            compute_bound = True
        else:
            compute_bound = self.records[st["last_record"]]["complete_s"] <= st["compute_end_s"]
        if compute_bound:
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": end - start, "queue_s": 0.0, "swap_s": 0.0,
                "network_s": 0.0, "contention_s": 0.0, "service_s": 0.0,
                "spread_s": end - min_finish,
            }
        else:
            crit = self.records[st["last_record"]]
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": crit["emit_s"] - start,
                "queue_s": (crit["dispatch_s"] - crit["emit_s"]) + crit["wait_s"],
                "swap_s": crit["swap_s"],
                "network_s": crit["link_s"],
                "contention_s": crit["contention_s"],
                "service_s": crit["exec_s"],
                "spread_s": end - min_finish,
            }
        self.steps.append(breakdown)
        nxt = step + 1
        if nxt < self.cfg["timesteps"]:
            self.events.push_class(self.clock_s, CLASS_ARRIVAL, ("step_start", nxt))

    # ------------------------------------------------------- routing

    def _on_request(self, rank, model, samples):
        self.pending.append([self.current_step, self.clock_s, None])
        id_ = self.core.submit(rank, model, samples)
        assert id_ == len(self.pending) - 1
        self._apply_effects()

    def _apply_effects(self):
        scheduled, dispatched, completed = self.core.take_effects()
        for d in dispatched:
            if d[0] == "direct":
                _, ids, idx, total, wait_s, swap_s, link_s, exec_s, complete_s = d
                for i in ids:
                    rank, model, samples = self.core.request(i)
                    meta = self.pending[i]
                    meta[2] = len(self.records)
                    self.records.append({
                        "id": i, "step": meta[0], "rank": rank, "model": model,
                        "samples": samples, "emit_s": meta[1],
                        "dispatch_s": self.clock_s,
                        "complete_s": complete_s, "backend": idx,
                        "batch_samples": total,
                        "wait_s": wait_s, "swap_s": swap_s, "link_s": link_s,
                        "contention_s": 0.0, "exec_s": exec_s,
                    })
            else:  # remote
                _, ids, idx, total, token = d
                assert token == len(self.rec0_of_token)
                self.rec0_of_token.append(len(self.records))
                for i in ids:
                    rank, model, samples = self.core.request(i)
                    meta = self.pending[i]
                    meta[2] = len(self.records)
                    self.records.append({
                        "id": i, "step": meta[0], "rank": rank, "model": model,
                        "samples": samples, "emit_s": meta[1],
                        "dispatch_s": self.clock_s,
                        "complete_s": math.nan, "backend": idx,
                        "batch_samples": total,
                        "wait_s": 0.0, "swap_s": 0.0, "link_s": 0.0,
                        "contention_s": 0.0, "exec_s": 0.0,
                    })
        for t, cls, ev in scheduled:
            self.events.push_class(t, cls, ev)
        for ids, token, timing in completed:
            if timing is not None:
                wait_s, swap_x, link_s, contention_s, exec_s = timing
                rec0 = self.rec0_of_token[token]
                for k in range(len(ids)):
                    r = self.records[rec0 + k]
                    r["complete_s"] = self.clock_s
                    r["wait_s"] = wait_s
                    r["swap_s"] = swap_x
                    r["link_s"] = link_s
                    r["contention_s"] = contention_s
                    r["exec_s"] = exec_s
            for i in ids:
                rank = self.core.req_meta[i][0]
                record = self.pending[i][2]
                st = self.ranks[rank]
                assert st["outstanding"] > 0
                st["outstanding"] -= 1
                st["last_record"] = record
                self._try_finish(rank)

    # ----------------------------------------------------- summary

    def time_to_solution_s(self):
        return self.steps[-1]["end_s"] if self.steps else 0.0

    def summary(self):
        latencies = [r["complete_s"] - r["emit_s"] for r in self.records]
        samples = sum(r["samples"] for r in self.records)
        straggler_counts = [0] * self.cfg["ranks"]
        totals = {"compute": 0.0, "queue": 0.0, "swap": 0.0, "network": 0.0,
                  "contention": 0.0, "service": 0.0}
        max_spread_s = 0.0
        for s in self.steps:
            straggler_counts[s["straggler"]] += 1
            totals["compute"] += s["compute_s"]
            totals["queue"] += s["queue_s"]
            totals["swap"] += s["swap_s"]
            totals["network"] += s["network_s"]
            totals["contention"] += s["contention_s"]
            totals["service"] += s["service_s"]
            max_spread_s = max(max_spread_s, s["spread_s"])
        tts = self.time_to_solution_s()
        return {
            "ranks": self.cfg["ranks"],
            "timesteps": len(self.steps),
            "requests": len(self.records),
            "samples": samples,
            "batches": self.batches,
            "time_to_solution_s": tts,
            "steps": self.steps,
            "total_compute_s": totals["compute"],
            "total_queue_s": totals["queue"],
            "total_swap_s": totals["swap"],
            "total_network_s": totals["network"],
            "total_contention_s": totals["contention"],
            "total_service_s": totals["service"],
            "latency": latency_dist(latencies),
            "swaps": self.swaps,
            "swap_time_s": self.swap_time_s,
            "straggler_counts": straggler_counts,
            "max_spread_s": max_spread_s,
            "mean_step_s": (tts / float(len(self.steps)) if self.steps else 0.0),
        }
