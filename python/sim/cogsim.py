"""eventsim::cogsim transliteration: the coupled CogSim engine
driving the simcore Pipeline.

The engine keeps only workload logic — the bulk-synchronous timestep
barrier, per-rank state, record keeping, and the control plane's
rank checkpoint/restart + reactive autoscaler; every dispatch/batch/
residency/fabric/service decision lives in simcore.Pipeline."""

import math

from equeue import CLASS_ARRIVAL, EventQueue
from eventsim import latency_dist, rank_rngs
from simcore import Pipeline
from workload import material_model


def validate_autoscaler(cfg, tier):
    # AutoscalerCfg.validate: dict keys initial, min_active,
    # max_active, low_s, high_s
    assert cfg["min_active"] >= 1, "autoscaler must keep one backend"
    assert cfg["min_active"] <= cfg["initial"] <= cfg["max_active"], \
        "autoscaler bounds must satisfy min <= initial <= max"
    assert cfg["max_active"] <= tier, \
        f"autoscaler max exceeds the tier size ({tier})"
    assert cfg["low_s"] >= 0.0 and cfg["high_s"] > cfg["low_s"] \
        and math.isfinite(cfg["high_s"])


class CogSim:
    def __init__(self, backends, policy, cfg, hermit_tier, mir_tier, fabric=None):
        # cfg keys: ranks, timesteps, compute_s, compute_jitter_s,
        # requests_per_step, models, samples_per_request, mir_every,
        # mir_samples, overlap, swap_s, residency_slots,
        # batching (None | (window_s, max_batch)), seed
        self.cfg = cfg
        self.core = Pipeline(backends, policy, hermit_tier, mir_tier,
                             cfg["batching"],
                             (cfg["residency_slots"], cfg["swap_s"]), fabric)
        self.events = EventQueue()
        self.rngs = rank_rngs(cfg["seed"], cfg["ranks"])
        self.ranks = [self._idle_rank() for _ in range(cfg["ranks"])]
        self.step_start_s = 0.0
        self.current_step = 0
        self.finished_ranks = 0
        # what the pipeline cannot know: [step, emit_s, record, epoch];
        # rank/model/samples live in core.req_meta, id-aligned
        self.pending = []
        self.records = []
        self.steps = []
        self.events_processed = 0
        # per-rank restart epoch: bumped on checkpoint/restart; events
        # and completions from older epochs are stale
        self.epoch = [0] * cfg["ranks"]
        # per-rank draws + physics duration of the current step — the
        # "checkpoint" a restarted rank replays (RNG not re-consumed)
        self.step_draws = [[] for _ in range(cfg["ranks"])]
        self.step_compute = [0.0] * cfg["ranks"]
        self.autoscaler = None
        self.rank_restarts = 0
        self.active_samples = []
        self.events.push_class(0.0, CLASS_ARRIVAL, ("step_start", 0))

    @staticmethod
    def _idle_rank():
        return {"compute_end_s": 0.0, "emit_s": 0.0, "outstanding": 0,
                "compute_done": False, "finished": False, "finish_s": 0.0,
                "last_record": None}

    def with_control(self, trace, autoscaler=None):
        # trace: list of (at_s, action) with action tuples as in
        # eventsim.with_control; autoscaler: dict (validate_autoscaler)
        for at_s, action in trace:
            assert at_s >= 0.0 and math.isfinite(at_s), \
                f"fleet event time must be finite and non-negative ({at_s})"
            self.events.push_class(at_s, CLASS_ARRIVAL, ("fleet", action))
        if autoscaler is not None:
            tier = list(self.core.hermit_tier)
            validate_autoscaler(autoscaler, len(tier))
            for idx in tier[autoscaler["initial"]:]:
                self.core.control_backend_leave(idx)
            # nothing is in flight at t = 0: deactivating idle
            # backends produces no observable effects
            self.core.take_effects()
            self.autoscaler = autoscaler

    # counters live on the pipeline
    @property
    def clock_s(self):
        return self.core.clock_s

    @property
    def submitted(self):
        return self.core.submitted

    @property
    def dispatched(self):
        return self.core.dispatched_n

    @property
    def completed(self):
        return self.core.completed_n

    def in_flight(self):
        return self.core.dispatched_n - self.core.retries_n - self.core.completed_n

    def retries(self):
        return self.core.retries_n

    def orphaned(self):
        return self.core.orphaned_n

    def parked(self):
        return self.core.parked_requests()

    def backend_active(self, idx):
        return self.core.is_active(idx)

    def active_count(self):
        return self.core.active_count()

    @property
    def batches(self):
        return self.core.batches

    @property
    def swaps(self):
        return self.core.swaps

    @property
    def swap_time_s(self):
        return self.core.swap_time_s

    def batcher_pending(self):
        return self.core.batcher_pending()

    # ------------------------------------------------------ run loop

    def _pump(self):
        popped = self.events.pop()
        if popped is None:
            return False
        t, event = popped
        self.events_processed += 1
        self.core.advance_to(t)
        self._handle(event)
        return True

    def run_to_completion(self):
        while self._pump():
            pass

    def _handle(self, event):
        kind = event[0]
        if kind == "step_start":
            self._on_step_start(event[1])
        elif kind == "arrival":
            self._on_request(event[1], event[2], event[3], event[4])
        elif kind == "compute_done":
            self._on_compute_done(event[1], event[2])
        elif kind == "fleet":
            self._on_fleet(event[1])
        else:
            self.core.handle(event)
            self._apply_effects()

    # ------------------------------------------------- timestep loop

    def _on_step_start(self, step):
        self._autoscale()
        self.active_samples.append(self.core.active_count())
        self.step_start_s = self.clock_s
        self.current_step = step
        self.finished_ranks = 0
        lo, hi = self.cfg["samples_per_request"]
        for rank in range(self.cfg["ranks"]):
            if self.cfg["compute_jitter_s"] > 0.0:
                jitter = self.rngs[rank].uniform(0.0, self.cfg["compute_jitter_s"])
            else:
                jitter = 0.0
            self.step_compute[rank] = self.cfg["compute_s"] + jitter
            draws = []
            for _ in range(self.cfg["requests_per_step"]):
                model = material_model(self.rngs[rank].below(self.cfg["models"]))
                samples = self.rngs[rank].range(lo, hi)
                draws.append((model, samples))
            if self.cfg["mir_every"] > 0 and step % self.cfg["mir_every"] == 0:
                draws.append(("mir", self.cfg["mir_samples"]))
            self.step_draws[rank] = draws
            self._emit_step(rank)

    def _emit_step(self, rank):
        # (re)start the rank's current step at the current clock; on a
        # checkpoint/restart the same stored draws replay (the
        # checkpoint is the step's input state, not a fresh sample)
        now = self.clock_s
        compute = self.step_compute[rank]
        emit_s = now + (1.0 - self.cfg["overlap"]) * compute
        compute_end_s = now + compute
        epoch = self.epoch[rank]
        outstanding = 0
        for model, samples in self.step_draws[rank]:
            self.events.push_class(emit_s, CLASS_ARRIVAL,
                                   ("arrival", rank, model, samples, epoch))
            outstanding += 1
        self.ranks[rank] = {
            "compute_end_s": compute_end_s, "emit_s": emit_s,
            "outstanding": outstanding, "compute_done": False,
            "finished": False, "finish_s": 0.0, "last_record": None,
        }
        self.events.push_class(compute_end_s, CLASS_ARRIVAL,
                               ("compute_done", rank, epoch))

    def _on_compute_done(self, rank, epoch):
        if epoch != self.epoch[rank]:
            return  # pre-failure physics: the restarted rank re-computes
        self.ranks[rank]["compute_done"] = True
        self._try_finish(rank)

    def _try_finish(self, rank):
        st = self.ranks[rank]
        if st["finished"] or not st["compute_done"] or st["outstanding"] > 0:
            return
        st["finished"] = True
        st["finish_s"] = self.clock_s
        self.finished_ranks += 1
        if self.finished_ranks == self.cfg["ranks"]:
            self._end_step()

    def _end_step(self):
        start = self.step_start_s
        end = self.clock_s
        step = self.current_step
        straggler = 0
        for r in range(1, self.cfg["ranks"]):
            if self.ranks[r]["finish_s"] > self.ranks[straggler]["finish_s"]:
                straggler = r
        min_finish = math.inf
        for r in self.ranks:
            min_finish = min(min_finish, r["finish_s"])
        st = self.ranks[straggler]
        if st["last_record"] is None:
            compute_bound = True
        else:
            compute_bound = self.records[st["last_record"]]["complete_s"] <= st["compute_end_s"]
        if compute_bound:
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": end - start, "queue_s": 0.0, "swap_s": 0.0,
                "network_s": 0.0, "contention_s": 0.0, "service_s": 0.0,
                "spread_s": end - min_finish,
            }
        else:
            crit = self.records[st["last_record"]]
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": crit["emit_s"] - start,
                "queue_s": (crit["dispatch_s"] - crit["emit_s"]) + crit["wait_s"],
                "swap_s": crit["swap_s"],
                "network_s": crit["link_s"],
                "contention_s": crit["contention_s"],
                "service_s": crit["exec_s"],
                "spread_s": end - min_finish,
            }
        self.steps.append(breakdown)
        nxt = step + 1
        if nxt < self.cfg["timesteps"]:
            self.events.push_class(self.clock_s, CLASS_ARRIVAL, ("step_start", nxt))

    # ------------------------------------------------- control plane

    def _on_fleet(self, action):
        kind = action[0]
        if kind == "leave":
            self.core.control_backend_leave(action[1])
            self._apply_effects()
        elif kind == "join":
            self.core.control_backend_join(action[1])
            self._apply_effects()
        elif kind == "degrade":
            self.core.control_link_scale(action[1])
            self._apply_effects()
        elif kind == "restore":
            self.core.control_link_scale(1.0)
            self._apply_effects()
        else:  # rankfail
            self._on_rank_fail(action[1])

    def _on_rank_fail(self, rank):
        # checkpoint/restart: the rank loses its in-flight timestep
        # and replays it from the step's input state; responses to the
        # lost attempt still arrive but count as waste
        assert rank < self.cfg["ranks"], f"unknown rank {rank}"
        if len(self.steps) >= self.cfg["timesteps"] or self.ranks[rank]["finished"]:
            return
        self.epoch[rank] += 1
        self.rank_restarts += 1
        self._emit_step(rank)

    def _autoscale(self):
        # reactive queue-depth autoscaling, one action per barrier:
        # grow by the lowest-index parked hermit backend on high mean
        # backlog, shrink the highest-index idle one on low
        cfg = self.autoscaler
        if cfg is None:
            return
        tier = list(self.core.hermit_tier)
        active = [i for i in tier if self.core.is_active(i)]
        if not active:
            if tier:
                self.core.control_backend_join(tier[0])
                self._apply_effects()
            return
        mean_backlog = sum(self.core.backlog_s(i) for i in active) / float(len(active))
        if mean_backlog > cfg["high_s"] and len(active) < cfg["max_active"]:
            parked = [i for i in tier if not self.core.is_active(i)]
            if parked:
                self.core.control_backend_join(parked[0])
                self._apply_effects()
        elif mean_backlog < cfg["low_s"] and len(active) > cfg["min_active"]:
            idle = [i for i in active
                    if self.core.live_batches[i] == 0 and self.core.backlog_s(i) <= 0.0]
            if idle:
                self.core.control_backend_leave(idle[-1])
                self._apply_effects()

    # ------------------------------------------------------- routing

    def _on_request(self, rank, model, samples, epoch):
        if epoch != self.epoch[rank]:
            return  # emitted before the failure: lost with the checkpoint
        self.pending.append([self.current_step, self.clock_s, None, epoch])
        id_ = self.core.submit(rank, model, samples)
        assert id_ == len(self.pending) - 1
        self._apply_effects()

    def _apply_effects(self):
        scheduled, dispatched, completed, orphaned = self.core.take_effects()
        # a backend left: void the orphans' completion state first —
        # each reappears in `dispatched` below with retry set
        for i in orphaned:
            rec = self.pending[i][2]
            assert rec is not None, "orphaned work was dispatched"
            r = self.records[rec]
            r["complete_s"] = math.nan
            r["retried"] = True
        for d in dispatched:
            if d[0] == "direct":
                _, ids, idx, total, wait_s, swap_s, link_s, exec_s, complete_s, retry = d
            else:  # remote
                _, ids, idx, total, token, retry = d
                wait_s = swap_s = link_s = exec_s = 0.0
                complete_s = math.nan
            if retry:
                # re-dispatch of orphaned work: the ids keep their one
                # record each; routing fields describe the new attempt
                for i in ids:
                    r = self.records[self.pending[i][2]]
                    r["dispatch_s"] = self.clock_s
                    r["complete_s"] = complete_s
                    r["backend"] = idx
                    r["batch_samples"] = total
                    r["wait_s"] = wait_s
                    r["swap_s"] = swap_s
                    r["link_s"] = link_s
                    r["contention_s"] = 0.0
                    r["exec_s"] = exec_s
                continue
            for i in ids:
                rank, model, samples = self.core.request(i)
                meta = self.pending[i]
                meta[2] = len(self.records)
                self.records.append({
                    "id": i, "step": meta[0], "rank": rank, "model": model,
                    "samples": samples, "emit_s": meta[1],
                    "dispatch_s": self.clock_s,
                    "complete_s": complete_s, "backend": idx,
                    "batch_samples": total,
                    "wait_s": wait_s, "swap_s": swap_s, "link_s": link_s,
                    "contention_s": 0.0, "exec_s": exec_s,
                    "retried": False,
                })
        for t, cls, ev in scheduled:
            self.events.push_class(t, cls, ev)
        for ids, token, timing in completed:
            if token is not None and timing is not None:
                # fabric path: fill the batch's records with measured
                # phase timings, addressed by id (identical to the old
                # contiguous-block fill on a static run, and correct
                # for retried batches with scattered records)
                wait_s, swap_x, link_s, contention_s, exec_s = timing
                for i in ids:
                    r = self.records[self.pending[i][2]]
                    r["complete_s"] = self.clock_s
                    r["wait_s"] = wait_s
                    r["swap_s"] = swap_x
                    r["link_s"] = link_s
                    r["contention_s"] = contention_s
                    r["exec_s"] = exec_s
            for i in ids:
                rank = self.core.req_meta[i][0]
                record = self.pending[i][2]
                if self.pending[i][3] != self.epoch[rank]:
                    continue  # wasted work from a pre-failure epoch
                st = self.ranks[rank]
                assert st["outstanding"] > 0
                st["outstanding"] -= 1
                st["last_record"] = record
                self._try_finish(rank)

    # ----------------------------------------------------- summary

    def time_to_solution_s(self):
        return self.steps[-1]["end_s"] if self.steps else 0.0

    def summary(self):
        # completed records only: orphaned-not-yet-recompleted work has
        # complete_s = NaN; retried completions are excluded from the
        # latency distribution (not first-attempt samples)
        finished = [r for r in self.records if math.isfinite(r["complete_s"])]
        latencies = [r["complete_s"] - r["emit_s"] for r in finished
                     if not r["retried"]]
        samples = sum(r["samples"] for r in finished)
        straggler_counts = [0] * self.cfg["ranks"]
        totals = {"compute": 0.0, "queue": 0.0, "swap": 0.0, "network": 0.0,
                  "contention": 0.0, "service": 0.0}
        max_spread_s = 0.0
        for s in self.steps:
            straggler_counts[s["straggler"]] += 1
            totals["compute"] += s["compute_s"]
            totals["queue"] += s["queue_s"]
            totals["swap"] += s["swap_s"]
            totals["network"] += s["network_s"]
            totals["contention"] += s["contention_s"]
            totals["service"] += s["service_s"]
            max_spread_s = max(max_spread_s, s["spread_s"])
        tts = self.time_to_solution_s()
        if self.active_samples:
            mean_active = sum(self.active_samples) / float(len(self.active_samples))
        else:
            mean_active = float(self.core.active_count())
        return {
            "ranks": self.cfg["ranks"],
            "timesteps": len(self.steps),
            "requests": len(finished),
            "samples": samples,
            "batches": self.batches,
            "time_to_solution_s": tts,
            "steps": self.steps,
            "total_compute_s": totals["compute"],
            "total_queue_s": totals["queue"],
            "total_swap_s": totals["swap"],
            "total_network_s": totals["network"],
            "total_contention_s": totals["contention"],
            "total_service_s": totals["service"],
            "latency": latency_dist(latencies),
            "swaps": self.swaps,
            "swap_time_s": self.swap_time_s,
            "straggler_counts": straggler_counts,
            "max_spread_s": max_spread_s,
            "mean_step_s": (tts / float(len(self.steps)) if self.steps else 0.0),
            "submitted": self.submitted,
            "retries": self.core.retries_n,
            "failed": self.submitted - len(finished) - self.core.batcher_pending(),
            "rank_restarts": self.rank_restarts,
            "mean_active_backends": mean_active,
        }
