"""eventsim::cogsim transliteration: the coupled CogSim engine."""

import math

import devices
import stats
from cluster import select
from equeue import CLASS_ARRIVAL, CLASS_COMPLETION, CLASS_DEADLINE, EventQueue
from eventsim import BatchStage, latency_dist, rank_rngs
from netsim import dir_payload_bytes
from workload import material_model


class Residency:
    def __init__(self, slots):
        self.slots = slots
        self.held = []

    def touch(self, model):
        if model in self.held:
            self.held.remove(model)
            self.held.append(model)
            return False
        self.held.append(model)
        if len(self.held) > self.slots:
            self.held.pop(0)
        return True


class CogSim:
    def __init__(self, backends, policy, cfg, hermit_tier, mir_tier, fabric=None):
        # cfg keys: ranks, timesteps, compute_s, compute_jitter_s,
        # requests_per_step, models, samples_per_request, mir_every,
        # mir_samples, overlap, swap_s, residency_slots,
        # batching (None | (window_s, max_batch)), seed
        self.cfg = cfg
        self.backends = backends
        self.policy = policy
        self.hermit_tier = hermit_tier
        self.mir_tier = mir_tier
        self.hermit_profile = devices.hermit()
        self.mir_profile = devices.mir_noln()
        self.rr_state = [0]
        self.affinity = {}
        self.residency = [Residency(cfg["residency_slots"]) for _ in backends]
        self.clock_s = 0.0
        self.events = EventQueue()
        self.batcher = (BatchStage(*cfg["batching"]) if cfg["batching"] else None)
        self.fabric = fabric
        self.transits = []
        self.swap_ready_s = {}   # (backend, model) -> landing time (inf = in transit)
        self.swap_waiters = {}   # (backend, model) -> [token]
        self.rngs = rank_rngs(cfg["seed"], cfg["ranks"])
        self.ranks = [self._idle_rank() for _ in range(cfg["ranks"])]
        self.step_start_s = 0.0
        self.current_step = 0
        self.finished_ranks = 0
        self.pending = []  # [step, rank, model, samples, emit_s, record]
        self.records = []
        self.steps = []
        self.submitted = 0
        self.dispatched = 0
        self.completed = 0
        self.batches = 0
        self.swaps = 0
        self.swap_time_s = 0.0
        self.events.push_class(0.0, CLASS_ARRIVAL, ("step_start", 0))

    @staticmethod
    def _idle_rank():
        return {"compute_end_s": 0.0, "emit_s": 0.0, "outstanding": 0,
                "compute_done": False, "finished": False, "finish_s": 0.0,
                "last_record": None}

    # ------------------------------------------------------ run loop

    def _pump(self):
        popped = self.events.pop()
        if popped is None:
            return False
        t, event = popped
        self._advance_clock(t)
        self._handle(event)
        return True

    def run_to_completion(self):
        while self._pump():
            pass

    def _advance_clock(self, t_s):
        dt = t_s - self.clock_s
        if dt <= 0.0:
            return
        for b in self.backends:
            b.drain_queue_s(dt)
        self.clock_s = t_s

    def _handle(self, event):
        kind = event[0]
        if kind == "step_start":
            self._on_step_start(event[1])
        elif kind == "arrival":
            self._on_request(event[1], event[2], event[3])
        elif kind == "compute_done":
            self._on_compute_done(event[1])
        elif kind == "deadline":
            self._pump_batcher()
        elif kind == "completion":
            self._on_completion(event[1])
        elif kind == "fabric_wake":
            self._on_fabric_wake(event[1])
        elif kind == "xfer_in":
            self._on_xfer_in_done(event[1])
        elif kind == "service_done":
            self._on_service_done(event[1])
        elif kind == "xfer_out":
            self._on_xfer_out_done(event[1])
        else:
            raise ValueError(kind)

    # ------------------------------------------------- timestep loop

    def _on_step_start(self, step):
        self.step_start_s = self.clock_s
        self.current_step = step
        self.finished_ranks = 0
        lo, hi = self.cfg["samples_per_request"]
        for rank in range(self.cfg["ranks"]):
            if self.cfg["compute_jitter_s"] > 0.0:
                jitter = self.rngs[rank].uniform(0.0, self.cfg["compute_jitter_s"])
            else:
                jitter = 0.0
            compute = self.cfg["compute_s"] + jitter
            emit_s = self.clock_s + (1.0 - self.cfg["overlap"]) * compute
            compute_end_s = self.clock_s + compute
            outstanding = 0
            for _ in range(self.cfg["requests_per_step"]):
                model = material_model(self.rngs[rank].below(self.cfg["models"]))
                samples = self.rngs[rank].range(lo, hi)
                self.events.push_class(emit_s, CLASS_ARRIVAL,
                                       ("arrival", rank, model, samples))
                outstanding += 1
            if self.cfg["mir_every"] > 0 and step % self.cfg["mir_every"] == 0:
                self.events.push_class(emit_s, CLASS_ARRIVAL,
                                       ("arrival", rank, "mir", self.cfg["mir_samples"]))
                outstanding += 1
            self.ranks[rank] = {
                "compute_end_s": compute_end_s, "emit_s": emit_s,
                "outstanding": outstanding, "compute_done": False,
                "finished": False, "finish_s": 0.0, "last_record": None,
            }
            self.events.push_class(compute_end_s, CLASS_ARRIVAL, ("compute_done", rank))

    def _on_compute_done(self, rank):
        self.ranks[rank]["compute_done"] = True
        self._try_finish(rank)

    def _try_finish(self, rank):
        st = self.ranks[rank]
        if st["finished"] or not st["compute_done"] or st["outstanding"] > 0:
            return
        st["finished"] = True
        st["finish_s"] = self.clock_s
        self.finished_ranks += 1
        if self.finished_ranks == self.cfg["ranks"]:
            self._end_step()

    def _end_step(self):
        start = self.step_start_s
        end = self.clock_s
        step = self.current_step
        straggler = 0
        for r in range(1, self.cfg["ranks"]):
            if self.ranks[r]["finish_s"] > self.ranks[straggler]["finish_s"]:
                straggler = r
        min_finish = math.inf
        for r in self.ranks:
            min_finish = min(min_finish, r["finish_s"])
        st = self.ranks[straggler]
        if st["last_record"] is None:
            compute_bound = True
        else:
            compute_bound = self.records[st["last_record"]]["complete_s"] <= st["compute_end_s"]
        if compute_bound:
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": end - start, "queue_s": 0.0, "swap_s": 0.0,
                "network_s": 0.0, "contention_s": 0.0, "service_s": 0.0,
                "spread_s": end - min_finish,
            }
        else:
            crit = self.records[st["last_record"]]
            breakdown = {
                "step": step, "start_s": start, "end_s": end, "straggler": straggler,
                "compute_s": crit["emit_s"] - start,
                "queue_s": (crit["dispatch_s"] - crit["emit_s"]) + crit["wait_s"],
                "swap_s": crit["swap_s"],
                "network_s": crit["link_s"],
                "contention_s": crit["contention_s"],
                "service_s": crit["exec_s"],
                "spread_s": end - min_finish,
            }
        self.steps.append(breakdown)
        nxt = step + 1
        if nxt < self.cfg["timesteps"]:
            self.events.push_class(self.clock_s, CLASS_ARRIVAL, ("step_start", nxt))

    # ------------------------------------------------------- routing

    def _on_request(self, rank, model, samples):
        self.submitted += 1
        id_ = len(self.pending)
        self.pending.append([self.current_step, rank, model, samples, self.clock_s, None])
        if self.batcher is not None:
            self.batcher.enqueue(model, id_, samples, self.clock_s)
            for ids in self.batcher.drain_size_ready():
                self._dispatch(ids)
            self._arm_batch_wakeup()
        else:
            self._dispatch([id_])

    def _arm_batch_wakeup(self):
        t = self.batcher.wakeup_at(self.clock_s)
        if t is not None:
            self.events.push_class(t, CLASS_DEADLINE, ("deadline",))

    def _pump_batcher(self):
        for ids in self.batcher.drain_ready(self.clock_s):
            self._dispatch(ids)
        self._arm_batch_wakeup()

    def _dispatch(self, ids):
        model = self.pending[ids[0]][2]
        total = sum(self.pending[i][3] for i in ids)
        is_mir = model.startswith("mir")
        profile = self.mir_profile if is_mir else self.hermit_profile
        candidates = self.mir_tier if is_mir else self.hermit_tier
        idx = select(self.policy, self.backends, self.rr_state, self.affinity,
                     candidates, model, profile, total)
        miss = self.residency[idx].touch(model)
        if miss:
            self.swaps += 1
        if self.fabric is not None and self.fabric.is_remote(idx):
            self._dispatch_remote(ids, idx, total, profile, miss)
            return
        swap_s = self.cfg["swap_s"] if miss else 0.0
        if miss:
            self.swap_time_s += swap_s
        backend = self.backends[idx]
        wait_s = backend.queue_s()
        link_s = backend.link_overhead_s(profile, total)
        exec_s = backend.execute_s(profile, total)
        latency_s = wait_s + swap_s + (link_s + exec_s)
        occupancy = backend.occupancy_s(profile, total) + swap_s
        backend.add_queue_s(occupancy)
        complete_s = self.clock_s + latency_s
        for i in ids:
            meta = self.pending[i]
            meta[5] = len(self.records)
            self.records.append({
                "id": i, "step": meta[0], "rank": meta[1], "model": meta[2],
                "samples": meta[3], "emit_s": meta[4], "dispatch_s": self.clock_s,
                "complete_s": complete_s, "backend": idx, "batch_samples": total,
                "wait_s": wait_s, "swap_s": swap_s, "link_s": link_s,
                "contention_s": 0.0, "exec_s": exec_s,
            })
        self.dispatched += len(ids)
        self.batches += 1
        self.events.push_class(complete_s, CLASS_COMPLETION, ("completion", ids))

    # ------------------------------------------------- fabric phases

    def _dispatch_remote(self, ids, idx, total, profile, miss):
        bytes_in, bytes_out = dir_payload_bytes(profile.input_elems, profile.output_elems, total)
        fab = self.fabric
        accel = fab.accel(idx)
        host = fab.host_of_rank(self.pending[ids[0]][1])
        ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out)
        swap_bytes = self.cfg["swap_s"] * fab.topology.link.eff_bandwidth
        backend = self.backends[idx]
        exec_s = backend.execute_s(profile, total)
        backend.add_queue_s(exec_s)
        rec0 = len(self.records)
        for i in ids:
            meta = self.pending[i]
            meta[5] = len(self.records)
            self.records.append({
                "id": i, "step": meta[0], "rank": meta[1], "model": meta[2],
                "samples": meta[3], "emit_s": meta[4], "dispatch_s": self.clock_s,
                "complete_s": math.nan, "backend": idx, "batch_samples": total,
                "wait_s": 0.0, "swap_s": 0.0, "link_s": 0.0,
                "contention_s": 0.0, "exec_s": 0.0,
            })
        self.dispatched += len(ids)
        self.batches += 1
        model = self.pending[ids[0]][2]
        token = len(self.transits)
        needs_swap_flow = miss and swap_bytes > 0.0
        if needs_swap_flow:
            self.swap_ready_s[(idx, model)] = math.inf
        self.transits.append({
            "ids": ids, "backend": idx, "accel": accel, "host": host,
            "model": model, "bytes_out": bytes_out, "dispatch_s": self.clock_s,
            "net_in_s": 0.0, "in_done_s": 0.0,
            "in_done": False, "swap_done": not needs_swap_flow, "started": False,
            "swap_excess_s": 0.0, "wait_s": 0.0, "exec_s": exec_s,
            "out_start_s": 0.0, "ideal_rtt_s": ideal_rtt_s, "rec0": rec0,
        })
        path = fab.topology.request_path(host, accel)
        flow = fab.engine.start(self.clock_s, path, bytes_in)
        fab.cont[flow] = ("in", token)
        if needs_swap_flow:
            spath = fab.topology.swap_path(accel)
            sflow = fab.engine.start(self.clock_s, spath, swap_bytes)
            fab.cont[sflow] = ("swap", token)
        self._arm_fabric()

    def _arm_fabric(self):
        armed = self.fabric.next_wake(self.clock_s)
        if armed is not None:
            t, version = armed
            self.events.push_class(t, CLASS_COMPLETION, ("fabric_wake", version))

    def _on_fabric_wake(self, version):
        fab = self.fabric
        conts = fab.drain_wake(version, self.clock_s)
        if conts is None:
            return
        for kind, token in conts:
            if kind == "in":
                fixed = fab.topology.dir_fixed_s(self.transits[token]["accel"])
                self.events.push_class(self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_in", token))
            elif kind == "swap":
                measured = self.clock_s - self.transits[token]["dispatch_s"]
                self.swap_time_s += measured
                self.transits[token]["swap_done"] = True
                key = (self.transits[token]["backend"], self.transits[token]["model"])
                self.swap_ready_s[key] = self.clock_s
                self._try_begin_service(token)
                for waiter in self.swap_waiters.pop(key, []):
                    self._try_begin_service(waiter)
            else:  # out
                fixed = fab.topology.dir_fixed_s(self.transits[token]["accel"])
                self.events.push_class(self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_out", token))
        self._arm_fabric()

    def _on_xfer_in_done(self, token):
        tr = self.transits[token]
        tr["net_in_s"] = self.clock_s - tr["dispatch_s"]
        tr["in_done_s"] = self.clock_s
        tr["in_done"] = True
        self._try_begin_service(token)

    def _try_begin_service(self, token):
        clock = self.clock_s
        tr = self.transits[token]
        if tr["started"] or not (tr["in_done"] and tr["swap_done"]):
            return
        key = (tr["backend"], tr["model"])
        if math.isinf(self.swap_ready_s.get(key, 0.0)):
            self.swap_waiters.setdefault(key, []).append(token)
            return
        wait_s, done_s = self.fabric.occupy(tr["backend"], clock, tr["exec_s"])
        backend = self.backends[tr["backend"]]
        deficit = (done_s - clock) - backend.queue_s()
        if deficit > 0.0:
            backend.add_queue_s(deficit)
        tr["started"] = True
        tr["swap_excess_s"] = clock - tr["in_done_s"]
        tr["wait_s"] = wait_s
        self.events.push_class(done_s, CLASS_COMPLETION, ("service_done", token))

    def _on_service_done(self, token):
        tr = self.transits[token]
        tr["out_start_s"] = self.clock_s
        fab = self.fabric
        path = fab.topology.response_path(tr["host"], tr["accel"])
        flow = fab.engine.start(self.clock_s, path, tr["bytes_out"])
        fab.cont[flow] = ("out", token)
        self._arm_fabric()

    def _on_xfer_out_done(self, token):
        tr = self.transits[token]
        net_out_s = self.clock_s - tr["out_start_s"]
        link_s = tr["net_in_s"] + net_out_s
        contention_s = max(link_s - tr["ideal_rtt_s"], 0.0)
        for k in range(len(tr["ids"])):
            r = self.records[tr["rec0"] + k]
            r["complete_s"] = self.clock_s
            r["wait_s"] = tr["wait_s"]
            r["swap_s"] = tr["swap_excess_s"]
            r["link_s"] = link_s
            r["contention_s"] = contention_s
            r["exec_s"] = tr["exec_s"]
        self._on_completion(tr["ids"])

    def _on_completion(self, ids):
        self.completed += len(ids)
        for i in ids:
            rank = self.pending[i][1]
            record = self.pending[i][5]
            st = self.ranks[rank]
            assert st["outstanding"] > 0
            st["outstanding"] -= 1
            st["last_record"] = record
            self._try_finish(rank)

    # ----------------------------------------------------- summary

    def time_to_solution_s(self):
        return self.steps[-1]["end_s"] if self.steps else 0.0

    def summary(self):
        latencies = [r["complete_s"] - r["emit_s"] for r in self.records]
        samples = sum(r["samples"] for r in self.records)
        straggler_counts = [0] * self.cfg["ranks"]
        totals = {"compute": 0.0, "queue": 0.0, "swap": 0.0, "network": 0.0,
                  "contention": 0.0, "service": 0.0}
        max_spread_s = 0.0
        for s in self.steps:
            straggler_counts[s["straggler"]] += 1
            totals["compute"] += s["compute_s"]
            totals["queue"] += s["queue_s"]
            totals["swap"] += s["swap_s"]
            totals["network"] += s["network_s"]
            totals["contention"] += s["contention_s"]
            totals["service"] += s["service_s"]
            max_spread_s = max(max_spread_s, s["spread_s"])
        tts = self.time_to_solution_s()
        return {
            "ranks": self.cfg["ranks"],
            "timesteps": len(self.steps),
            "requests": len(self.records),
            "samples": samples,
            "batches": self.batches,
            "time_to_solution_s": tts,
            "steps": self.steps,
            "total_compute_s": totals["compute"],
            "total_queue_s": totals["queue"],
            "total_swap_s": totals["swap"],
            "total_network_s": totals["network"],
            "total_contention_s": totals["contention"],
            "total_service_s": totals["service"],
            "latency": latency_dist(latencies),
            "swaps": self.swaps,
            "swap_time_s": self.swap_time_s,
            "straggler_counts": straggler_counts,
            "max_spread_s": max_spread_s,
            "mean_step_s": (tts / float(len(self.steps)) if self.steps else 0.0),
        }
