"""eventsim transliteration: BatchStage, FabricLayer, EventSim."""

import math

import devices
import stats
from batcher import DynamicBatcher, PendingRequest
from cluster import select
from equeue import CLASS_ARRIVAL, CLASS_COMPLETION, CLASS_DEADLINE, EventQueue
from fabric import FabricEngine
from netsim import dir_payload_bytes
from rng import Rng
from rustfloat import MASK64, dur_as_secs_f64, dur_from_secs_f64
from workload import material_model

HIST_EDGES_US = [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3,
                 1e4, 2e4, 5e4, 1e5, 2e5, 5e5, 1e6]


def latency_dist(xs):
    histogram = [[e, 0] for e in HIST_EDGES_US]
    overflow = 0
    for x in xs:
        us = x * 1e6
        for bucket in histogram:
            if us <= bucket[0]:
                bucket[1] += 1
                break
        else:
            overflow += 1
    return {
        "count": len(xs),
        "mean_s": stats.mean(xs),
        "p50_s": stats.percentile(xs, 50.0),
        "p90_s": stats.percentile(xs, 90.0),
        "p99_s": stats.percentile(xs, 99.0),
        "p999_s": stats.percentile(xs, 99.9),
        "max_s": max(xs) if xs else 0.0,
        "histogram": [(e, c) for e, c in histogram],
        "overflow": overflow,
    }


class BatchStage:
    def __init__(self, window_s, max_batch):
        assert window_s >= 0.0 and math.isfinite(window_s)
        assert max_batch >= 1
        self.batcher = DynamicBatcher(max_batch, dur_from_secs_f64(window_s), max_batch)
        self.pending = 0

    @staticmethod
    def inst(t_s):
        return dur_from_secs_f64(t_s)

    def enqueue(self, instance, id_, samples, clock_s):
        self.batcher.enqueue(instance, PendingRequest(id_, samples, self.inst(clock_s)))
        self.pending += 1

    def drain_size_ready(self):
        out = []
        while self.batcher.has_size_ready():
            for batch in self.batcher.drain_size_ready():
                self.pending -= len(batch.requests)
                out.append([r.id for r in batch.requests])
        return out

    def drain_ready(self, clock_s):
        now = self.inst(clock_s)
        out = []
        while self.batcher.has_ready(now):
            for batch in self.batcher.drain_ready(now):
                self.pending -= len(batch.requests)
                out.append([r.id for r in batch.requests])
        return out

    def wakeup_at(self, clock_s):
        now = self.inst(clock_s)
        if self.batcher.has_ready(now):
            return clock_s
        d = self.batcher.next_deadline(now)
        if d is None:
            return None
        return max(dur_as_secs_f64(d), clock_s)


class FabricLayer:
    def __init__(self, topology, accel_of_backend, n_backends):
        assert len(accel_of_backend) == n_backends
        self.topology = topology
        self.accel_of_backend = accel_of_backend
        self.engine = FabricEngine(topology)
        self.cont = {}  # flow id -> ("in"|"swap"|"out", token)
        self.wake_version = 0
        self.busy_until_s = [0.0] * n_backends

    def is_remote(self, backend):
        return self.topology.is_pooled(self.accel_of_backend[backend])

    def accel(self, backend):
        return self.accel_of_backend[backend]

    def host_of_rank(self, rank):
        return rank % self.topology.hosts

    def ideal_rtt_s(self, bytes_total):
        return self.topology.link.rtt_overhead_s(bytes_total)

    def occupy(self, backend, ready_s, exec_s):
        start_s = max(ready_s, self.busy_until_s[backend])
        done_s = start_s + exec_s
        self.busy_until_s[backend] = done_s
        return start_s - ready_s, done_s

    def drain_wake(self, version, clock_s):
        if version != self.wake_version:
            return None
        done = self.engine.take_completed(clock_s)
        return [self.cont.pop(f) for f in done]

    def next_wake(self, clock_s):
        t = self.engine.next_completion_s()
        if t is None:
            return None
        self.wake_version += 1
        return (max(t, clock_s), self.wake_version)


def rank_rngs(seed, ranks):
    return [Rng(seed ^ (((r + 1) * 0x9E3779B97F4A7C15) & MASK64)) for r in range(ranks)]


# Arrival processes: ("synchronized", period, jitter) |
# ("poisson", rate) | ("closed_loop", think)


class EventSim:
    def __init__(self, backends, policy, cfg, hermit_tier, mir_tier, fabric=None):
        # cfg: dict with ranks, materials, samples_per_request,
        # requests_per_burst, mir_every, mir_samples, arrival,
        # batching (None | (window_s, max_batch)), horizon_s, seed
        self.cfg = cfg
        self.backends = backends
        self.policy = policy
        self.hermit_tier = hermit_tier
        self.mir_tier = mir_tier
        self.hermit_profile = devices.hermit()
        self.mir_profile = devices.mir_noln()
        self.rr_state = [0]
        self.affinity = {}
        self.clock_s = 0.0
        self.events = EventQueue()
        self.batcher = (BatchStage(*cfg["batching"]) if cfg["batching"] else None)
        self.fabric = fabric
        self.transits = []
        self.rngs = rank_rngs(cfg["seed"], cfg["ranks"])
        self.pending = []   # (rank, model, samples, arrival_s)
        self.records = []   # dicts
        self.submitted = 0
        self.dispatched = 0
        self.completed = 0
        self.batches = 0
        self.events_processed = 0
        self._seed_generators()

    # ---------------------------------------------------- generators

    def _seed_generators(self):
        kind = self.cfg["arrival"][0]
        if kind == "synchronized":
            self.events.push(0.0, ("burst", 0))
        elif kind == "poisson":
            rate = self.cfg["arrival"][1]
            assert rate > 0.0
            for rank in range(self.cfg["ranks"]):
                t = self.rngs[rank].exponential(rate)
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("poisson", rank))
        else:  # closed_loop
            think = self.cfg["arrival"][1]
            for rank in range(self.cfg["ranks"]):
                t = self.rngs[rank].uniform(0.0, max(think, 1e-6))
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("closed", rank))

    def gen_hermit(self, rank):
        lo, hi = self.cfg["samples_per_request"]
        rng = self.rngs[rank]
        model = material_model(rng.below(self.cfg["materials"]))
        samples = rng.range(lo, hi)
        return model, samples

    # ------------------------------------------------------ run loop

    def step(self):
        popped = self.events.pop()
        if popped is None:
            return False
        t, event = popped
        self.events_processed += 1
        self._advance_clock(t)
        self._handle(event)
        return True

    def run_to_completion(self):
        while self.step():
            pass

    def _advance_clock(self, t_s):
        dt = t_s - self.clock_s
        if dt <= 0.0:
            return
        for b in self.backends:
            b.drain_queue_s(dt)
        self.clock_s = t_s

    def _handle(self, event):
        kind = event[0]
        if kind == "burst":
            self._on_burst(event[1])
        elif kind == "arrival":
            self._on_request(event[1], event[2], event[3])
        elif kind == "poisson":
            self._on_poisson(event[1])
        elif kind == "closed":
            self._on_closed(event[1])
        elif kind == "deadline":
            self._pump_batcher()
        elif kind == "completion":
            self._on_completion(event[1])
        elif kind == "fabric_wake":
            self._on_fabric_wake(event[1])
        elif kind == "xfer_in":
            self._on_xfer_in_done(event[1])
        elif kind == "service_done":
            self._on_service_done(event[1])
        elif kind == "xfer_out":
            self._on_xfer_out_done(event[1])
        else:
            raise ValueError(kind)

    def _on_burst(self, step):
        _, period_s, jitter_s = self.cfg["arrival"]
        t0 = float(step) * period_s
        for rank in range(self.cfg["ranks"]):
            for _ in range(self.cfg["requests_per_burst"]):
                model, samples = self.gen_hermit(rank)
                jitter = self.rngs[rank].uniform(0.0, jitter_s) if jitter_s > 0.0 else 0.0
                t = t0 + jitter
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("arrival", rank, model, samples))
            if self.cfg["mir_every"] > 0 and step % self.cfg["mir_every"] == 0:
                self.events.push(t0, ("arrival", rank, "mir", self.cfg["mir_samples"]))
        nxt = float(step + 1) * period_s
        if nxt <= self.cfg["horizon_s"]:
            self.events.push(nxt, ("burst", step + 1))

    def _on_poisson(self, rank):
        rate = self.cfg["arrival"][1]
        model, samples = self.gen_hermit(rank)
        nxt = self.clock_s + self.rngs[rank].exponential(rate)
        if nxt <= self.cfg["horizon_s"]:
            self.events.push(nxt, ("poisson", rank))
        self._on_request(rank, model, samples)

    def _on_closed(self, rank):
        model, samples = self.gen_hermit(rank)
        self._on_request(rank, model, samples)

    # ------------------------------------------------------- routing

    def _on_request(self, rank, model, samples):
        self.submitted += 1
        id_ = len(self.pending)
        self.pending.append((rank, model, samples, self.clock_s))
        if self.batcher is not None:
            self.batcher.enqueue(model, id_, samples, self.clock_s)
            for ids in self.batcher.drain_size_ready():
                self._dispatch(ids)
            self._arm_batch_wakeup()
        else:
            self._dispatch([id_])

    def _arm_batch_wakeup(self):
        t = self.batcher.wakeup_at(self.clock_s)
        if t is not None:
            self.events.push_class(t, CLASS_DEADLINE, ("deadline",))

    def _pump_batcher(self):
        for ids in self.batcher.drain_ready(self.clock_s):
            self._dispatch(ids)
        self._arm_batch_wakeup()

    def _dispatch(self, ids):
        rank0, model, _, _ = self.pending[ids[0]]
        total = sum(self.pending[i][2] for i in ids)
        is_mir = model.startswith("mir")
        profile = self.mir_profile if is_mir else self.hermit_profile
        candidates = self.mir_tier if is_mir else self.hermit_tier
        idx = select(self.policy, self.backends, self.rr_state, self.affinity,
                     candidates, model, profile, total)
        if self.fabric is not None and self.fabric.is_remote(idx):
            self._dispatch_remote(ids, idx, total, profile)
            return
        backend = self.backends[idx]
        wait_s = backend.queue_s()
        link_overhead_s = backend.link_overhead_s(profile, total)
        latency_s = wait_s + backend.latency_s(profile, total)
        occupancy = backend.occupancy_s(profile, total)
        backend.add_queue_s(occupancy)
        complete_s = self.clock_s + latency_s
        for i in ids:
            rank, m, samples, arrival_s = self.pending[i]
            self.records.append({
                "id": i, "rank": rank, "model": m, "samples": samples,
                "arrival_s": arrival_s, "dispatch_s": self.clock_s,
                "complete_s": complete_s, "backend": idx, "batch_samples": total,
                "link_overhead_s": link_overhead_s, "contention_s": 0.0,
            })
        self.dispatched += len(ids)
        self.batches += 1
        self.events.push_class(complete_s, CLASS_COMPLETION, ("completion", ids))

    # ------------------------------------------------- fabric phases

    def _dispatch_remote(self, ids, idx, total, profile):
        bytes_in, bytes_out = dir_payload_bytes(profile.input_elems, profile.output_elems, total)
        fab = self.fabric
        accel = fab.accel(idx)
        host = fab.host_of_rank(self.pending[ids[0]][0])
        ideal_rtt_s = fab.ideal_rtt_s(bytes_in + bytes_out)
        backend = self.backends[idx]
        exec_s = backend.execute_s(profile, total)
        backend.add_queue_s(exec_s)
        rec0 = len(self.records)
        for i in ids:
            rank, m, samples, arrival_s = self.pending[i]
            self.records.append({
                "id": i, "rank": rank, "model": m, "samples": samples,
                "arrival_s": arrival_s, "dispatch_s": self.clock_s,
                "complete_s": math.nan, "backend": idx, "batch_samples": total,
                "link_overhead_s": 0.0, "contention_s": 0.0,
            })
        self.dispatched += len(ids)
        self.batches += 1
        token = len(self.transits)
        self.transits.append({
            "ids": ids, "backend": idx, "accel": accel, "host": host,
            "bytes_out": bytes_out, "dispatch_s": self.clock_s,
            "net_in_s": 0.0,
            "exec_s": exec_s, "out_start_s": 0.0, "ideal_rtt_s": ideal_rtt_s,
            "rec0": rec0,
        })
        path = fab.topology.request_path(host, accel)
        flow = fab.engine.start(self.clock_s, path, bytes_in)
        fab.cont[flow] = ("in", token)
        self._arm_fabric()

    def _arm_fabric(self):
        armed = self.fabric.next_wake(self.clock_s)
        if armed is not None:
            t, version = armed
            self.events.push_class(t, CLASS_COMPLETION, ("fabric_wake", version))

    def _on_fabric_wake(self, version):
        fab = self.fabric
        conts = fab.drain_wake(version, self.clock_s)
        if conts is None:
            return
        for kind, token in conts:
            fixed = fab.topology.dir_fixed_s(self.transits[token]["accel"])
            if kind == "in":
                self.events.push_class(self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_in", token))
            elif kind == "out":
                self.events.push_class(self.clock_s + fixed, CLASS_COMPLETION,
                                       ("xfer_out", token))
            else:
                raise AssertionError("EventSim starts no swap flows")
        self._arm_fabric()

    def _on_xfer_in_done(self, token):
        clock = self.clock_s
        tr = self.transits[token]
        _wait_s, done_s = self.fabric.occupy(tr["backend"], clock, tr["exec_s"])
        backend = self.backends[tr["backend"]]
        deficit = (done_s - clock) - backend.queue_s()
        if deficit > 0.0:
            backend.add_queue_s(deficit)
        tr["net_in_s"] = clock - tr["dispatch_s"]
        self.events.push_class(done_s, CLASS_COMPLETION, ("service_done", token))

    def _on_service_done(self, token):
        tr = self.transits[token]
        tr["out_start_s"] = self.clock_s
        fab = self.fabric
        path = fab.topology.response_path(tr["host"], tr["accel"])
        flow = fab.engine.start(self.clock_s, path, tr["bytes_out"])
        fab.cont[flow] = ("out", token)
        self._arm_fabric()

    def _on_xfer_out_done(self, token):
        tr = self.transits[token]
        net_out_s = self.clock_s - tr["out_start_s"]
        link_s = tr["net_in_s"] + net_out_s
        contention_s = max(link_s - tr["ideal_rtt_s"], 0.0)
        for k in range(len(tr["ids"])):
            r = self.records[tr["rec0"] + k]
            r["complete_s"] = self.clock_s
            r["link_overhead_s"] = link_s
            r["contention_s"] = contention_s
        self._on_completion(tr["ids"])

    def _on_completion(self, ids):
        self.completed += len(ids)
        if self.cfg["arrival"][0] == "closed_loop":
            think = self.cfg["arrival"][1]
            for i in ids:
                rank = self.pending[i][0]
                t = self.clock_s + think
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("closed", rank))

    # ----------------------------------------------------- summary

    def summary(self):
        records = [r for r in self.records if math.isfinite(r["complete_s"])]
        latencies = [r["complete_s"] - r["arrival_s"] for r in records]
        samples = sum(r["samples"] for r in records)
        makespan_s = 0.0
        for r in records:
            makespan_s = max(makespan_s, r["complete_s"])
        ranks = self.cfg["ranks"]
        rank_sum = [0.0] * ranks
        rank_n = [0] * ranks
        link_sum = 0.0
        contention_sum = 0.0
        for r in records:
            rank_sum[r["rank"]] += r["complete_s"] - r["arrival_s"]
            rank_n[r["rank"]] += 1
            link_sum += r["link_overhead_s"]
            contention_sum += r["contention_s"]
        per_rank_mean_s = [s / float(n) if n > 0 else 0.0 for s, n in zip(rank_sum, rank_n)]
        active = [m for m, n in zip(per_rank_mean_s, rank_n) if n > 0]
        if active:
            mn = min(active)
            mx = max(active)
            slowdown_max = mx / mn if (mn > 0.0 and math.isfinite(mn)) else 1.0
        else:
            slowdown_max = 1.0
        n_rec = len(records)
        return {
            "requests": n_rec,
            "samples": samples,
            "batches": self.batches,
            "mean_batch_samples": (float(samples) / float(self.batches)
                                   if self.batches > 0 else 0.0),
            "latency": latency_dist(latencies),
            "mean_link_overhead_s": (link_sum / float(n_rec) if n_rec else 0.0),
            "mean_contention_s": (contention_sum / float(n_rec) if n_rec else 0.0),
            "per_rank_mean_s": per_rank_mean_s,
            "slowdown_max": slowdown_max,
            "makespan_s": makespan_s,
            "samples_per_s": (float(samples) / makespan_s if makespan_s > 0.0 else 0.0),
        }
