"""eventsim transliteration: EventSim driving the simcore Pipeline.

The engine keeps only workload logic — arrival generators and record
keeping; every dispatch/batch/fabric/service decision lives in
simcore.Pipeline (mirrors rust/src/simcore/)."""

import math

import stats
from equeue import EventQueue
from rng import Rng
from rustfloat import MASK64
from simcore import BatchStage, FabricLayer, Pipeline  # noqa: F401 (re-export)
from workload import material_model

HIST_EDGES_US = [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3,
                 1e4, 2e4, 5e4, 1e5, 2e5, 5e5, 1e6]


def latency_dist(xs):
    # Non-finite entries — requests that never completed — are
    # excluded, not recorded as 0-latency samples: quantiles describe
    # completions only (the caller reports the failed count apart).
    xs = [x for x in xs if math.isfinite(x)]
    histogram = [[e, 0] for e in HIST_EDGES_US]
    overflow = 0
    for x in xs:
        us = x * 1e6
        for bucket in histogram:
            if us <= bucket[0]:
                bucket[1] += 1
                break
        else:
            overflow += 1
    return {
        "count": len(xs),
        "mean_s": stats.mean(xs),
        "p50_s": stats.percentile(xs, 50.0),
        "p90_s": stats.percentile(xs, 90.0),
        "p99_s": stats.percentile(xs, 99.0),
        "p999_s": stats.percentile(xs, 99.9),
        "max_s": max(xs) if xs else 0.0,
        "histogram": [(e, c) for e, c in histogram],
        "overflow": overflow,
    }


def rank_rngs(seed, ranks):
    return [Rng(seed ^ (((r + 1) * 0x9E3779B97F4A7C15) & MASK64)) for r in range(ranks)]


# Arrival processes: ("synchronized", period, jitter) |
# ("poisson", rate) | ("closed_loop", think)


class EventSim:
    def __init__(self, backends, policy, cfg, hermit_tier, mir_tier, fabric=None):
        # cfg: dict with ranks, materials, samples_per_request,
        # requests_per_burst, mir_every, mir_samples, arrival,
        # batching (None | (window_s, max_batch)), horizon_s, seed
        self.cfg = cfg
        self.core = Pipeline(backends, policy, hermit_tier, mir_tier,
                             cfg["batching"], None, fabric)
        self.events = EventQueue()
        self.rngs = rank_rngs(cfg["seed"], cfg["ranks"])
        # per-request emission time; rank/model/samples live in the
        # pipeline's metadata store (core.req_meta), id-aligned
        self.arrival_s = []
        self.records = []        # dicts
        # request id -> record index (None until dispatched); retries
        # update a request's one record in place, so completions
        # address records by id, not by batch block
        self.rec_of_id = []
        self.events_processed = 0
        self._seed_generators()

    def with_control(self, trace):
        """Arm a control-plane trace: each (at_s, action) fires as an
        ordinary arrival-class event.  An empty trace adds nothing —
        the run is bit-identical to a static one.  Actions: ("leave",
        idx) | ("join", idx) | ("degrade", factor) | ("restore",) |
        ("rankfail", rank) — rank failures are a coupled-engine
        concept and are ignored here."""
        for at_s, action in trace:
            assert at_s >= 0.0 and math.isfinite(at_s), \
                f"fleet event time must be finite and non-negative ({at_s})"
            self.events.push(at_s, ("fleet", action))

    # counters live on the pipeline
    @property
    def clock_s(self):
        return self.core.clock_s

    @property
    def submitted(self):
        return self.core.submitted

    @property
    def dispatched(self):
        return self.core.dispatched_n

    @property
    def completed(self):
        return self.core.completed_n

    @property
    def batches(self):
        return self.core.batches

    def batcher_pending(self):
        return self.core.batcher_pending()

    def in_flight(self):
        # dispatched at least once but not yet completed (includes
        # orphaned work parked with no live backend)
        return self.core.dispatched_n - self.core.retries_n - self.core.completed_n

    def retries(self):
        return self.core.retries_n

    def orphaned(self):
        return self.core.orphaned_n

    def parked(self):
        return self.core.parked_requests()

    def backend_active(self, idx):
        return self.core.is_active(idx)

    # ---------------------------------------------------- generators

    def _seed_generators(self):
        kind = self.cfg["arrival"][0]
        if kind == "synchronized":
            self.events.push(0.0, ("burst", 0))
        elif kind == "poisson":
            rate = self.cfg["arrival"][1]
            assert rate > 0.0
            for rank in range(self.cfg["ranks"]):
                t = self.rngs[rank].exponential(rate)
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("poisson", rank))
        else:  # closed_loop
            think = self.cfg["arrival"][1]
            for rank in range(self.cfg["ranks"]):
                t = self.rngs[rank].uniform(0.0, max(think, 1e-6))
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("closed", rank))

    def gen_hermit(self, rank):
        lo, hi = self.cfg["samples_per_request"]
        rng = self.rngs[rank]
        model = material_model(rng.below(self.cfg["materials"]))
        samples = rng.range(lo, hi)
        return model, samples

    # ------------------------------------------------------ run loop

    def step(self):
        popped = self.events.pop()
        if popped is None:
            return False
        t, event = popped
        self.events_processed += 1
        self.core.advance_to(t)
        self._handle(event)
        return True

    def run_to_completion(self):
        while self.step():
            pass

    def _handle(self, event):
        kind = event[0]
        if kind == "burst":
            self._on_burst(event[1])
        elif kind == "arrival":
            self._on_request(event[1], event[2], event[3])
        elif kind == "poisson":
            self._on_poisson(event[1])
        elif kind == "closed":
            self._on_closed(event[1])
        elif kind == "fleet":
            self._on_fleet(event[1])
        else:
            self.core.handle(event)
            self._apply_effects()

    def _on_fleet(self, action):
        verb = action[0]
        if verb == "leave":
            self.core.control_backend_leave(action[1])
        elif verb == "join":
            self.core.control_backend_join(action[1])
        elif verb == "degrade":
            self.core.control_link_scale(action[1])
        elif verb == "restore":
            self.core.control_link_scale(1.0)
        elif verb == "rankfail":
            pass  # no rank-owned state to replay here
        else:
            raise ValueError(verb)
        self._apply_effects()

    def _on_burst(self, step):
        _, period_s, jitter_s = self.cfg["arrival"]
        t0 = float(step) * period_s
        for rank in range(self.cfg["ranks"]):
            for _ in range(self.cfg["requests_per_burst"]):
                model, samples = self.gen_hermit(rank)
                jitter = self.rngs[rank].uniform(0.0, jitter_s) if jitter_s > 0.0 else 0.0
                t = t0 + jitter
                if t <= self.cfg["horizon_s"]:
                    self.events.push(t, ("arrival", rank, model, samples))
            if self.cfg["mir_every"] > 0 and step % self.cfg["mir_every"] == 0:
                self.events.push(t0, ("arrival", rank, "mir", self.cfg["mir_samples"]))
        nxt = float(step + 1) * period_s
        if nxt <= self.cfg["horizon_s"]:
            self.events.push(nxt, ("burst", step + 1))

    def _on_poisson(self, rank):
        rate = self.cfg["arrival"][1]
        model, samples = self.gen_hermit(rank)
        nxt = self.clock_s + self.rngs[rank].exponential(rate)
        if nxt <= self.cfg["horizon_s"]:
            self.events.push(nxt, ("poisson", rank))
        self._on_request(rank, model, samples)

    def _on_closed(self, rank):
        model, samples = self.gen_hermit(rank)
        self._on_request(rank, model, samples)

    # ------------------------------------------------------- routing

    def _on_request(self, rank, model, samples):
        self.arrival_s.append(self.clock_s)
        self.rec_of_id.append(None)
        id_ = self.core.submit(rank, model, samples)
        assert id_ == len(self.arrival_s) - 1
        self._apply_effects()

    def _apply_effects(self):
        scheduled, dispatched, completed, orphaned = self.core.take_effects()
        # a backend left: void the orphans' completion state first —
        # each reappears in `dispatched` below with retry set
        for i in orphaned:
            r = self.records[self.rec_of_id[i]]
            r["complete_s"] = math.nan
            r["retried"] = True
        for d in dispatched:
            if d[0] == "direct":
                (_, ids, idx, total, _wait_s, _swap_s, link_s, _exec_s,
                 complete_s, retry) = d
            else:  # remote
                _, ids, idx, total, _token, retry = d
                complete_s, link_s = math.nan, 0.0
            if retry:
                # re-dispatch of orphaned work: the ids keep their one
                # record each; the routing fields describe the new
                # attempt
                for i in ids:
                    r = self.records[self.rec_of_id[i]]
                    r["dispatch_s"] = self.clock_s
                    r["complete_s"] = complete_s
                    r["backend"] = idx
                    r["batch_samples"] = total
                    r["link_overhead_s"] = link_s
                    r["contention_s"] = 0.0
                continue
            for i in ids:
                rank, m, samples = self.core.request(i)
                self.rec_of_id[i] = len(self.records)
                self.records.append({
                    "id": i, "rank": rank, "model": m, "samples": samples,
                    "arrival_s": self.arrival_s[i], "dispatch_s": self.clock_s,
                    "complete_s": complete_s, "backend": idx,
                    "batch_samples": total,
                    "link_overhead_s": link_s, "contention_s": 0.0,
                    "retried": False,
                })
        for t, cls, ev in scheduled:
            self.events.push_class(t, cls, ev)
        for ids, token, timing in completed:
            if token is not None and timing is not None:
                # fabric path: fill the batch's records with measured
                # timings, addressed by id
                _wait_s, _swap_x, link_s, contention_s, _exec_s = timing
                for i in ids:
                    r = self.records[self.rec_of_id[i]]
                    r["complete_s"] = self.clock_s
                    r["link_overhead_s"] = link_s
                    r["contention_s"] = contention_s
            if self.cfg["arrival"][0] == "closed_loop":
                think = self.cfg["arrival"][1]
                for i in ids:
                    rank = self.core.req_meta[i][0]
                    t = self.clock_s + think
                    if t <= self.cfg["horizon_s"]:
                        self.events.push(t, ("closed", rank))

    # ----------------------------------------------------- summary

    def summary(self):
        records = [r for r in self.records if math.isfinite(r["complete_s"])]
        # first-attempt latencies only: a retried completion's chain
        # includes the failure gap and is counted via `retries`
        latencies = [r["complete_s"] - r["arrival_s"] for r in records
                     if not r["retried"]]
        samples = sum(r["samples"] for r in records)
        makespan_s = 0.0
        for r in records:
            makespan_s = max(makespan_s, r["complete_s"])
        ranks = self.cfg["ranks"]
        rank_sum = [0.0] * ranks
        rank_n = [0] * ranks
        link_sum = 0.0
        contention_sum = 0.0
        for r in records:
            rank_sum[r["rank"]] += r["complete_s"] - r["arrival_s"]
            rank_n[r["rank"]] += 1
            link_sum += r["link_overhead_s"]
            contention_sum += r["contention_s"]
        per_rank_mean_s = [s / float(n) if n > 0 else 0.0 for s, n in zip(rank_sum, rank_n)]
        active = [m for m, n in zip(per_rank_mean_s, rank_n) if n > 0]
        if active:
            mn = min(active)
            mx = max(active)
            slowdown_max = mx / mn if (mn > 0.0 and math.isfinite(mn)) else 1.0
        else:
            slowdown_max = 1.0
        n_rec = len(records)
        return {
            "requests": n_rec,
            "samples": samples,
            "batches": self.batches,
            "mean_batch_samples": (float(samples) / float(self.batches)
                                   if self.batches > 0 else 0.0),
            "latency": latency_dist(latencies),
            "mean_link_overhead_s": (link_sum / float(n_rec) if n_rec else 0.0),
            "mean_contention_s": (contention_sum / float(n_rec) if n_rec else 0.0),
            "per_rank_mean_s": per_rank_mean_s,
            "slowdown_max": slowdown_max,
            "makespan_s": makespan_s,
            "samples_per_s": (float(samples) / makespan_s if makespan_s > 0.0 else 0.0),
            "submitted": self.core.submitted,
            "retries": self.core.retries_n,
            "failed": self.core.submitted - n_rec - self.core.batcher_pending(),
        }
