"""harness control-plane transliteration: ControlSpec parsing, the
seven-cell control campaign (harness::sweep), and its JSON document
(harness::report) — the mirror that regenerates
rust/tests/golden/control_summary.json byte-exactly."""

import math

from campaign import build_fabric_spec, build_fleet, fixed3, us
from cluster import LEAST_OUTSTANDING
from cogsim import CogSim
from eventsim import FabricLayer
from netsim import Link

# The autoscaler must hold TTS within this factor of the statically-
# provisioned optimum (report::AUTOSCALER_BOUND).
AUTOSCALER_BOUND = 2.0


def static_spec():
    return {"key": "static", "trace": [], "autoscaler": None}


def is_static(spec):
    return not spec["trace"] and spec["autoscaler"] is None


def parse_control(s):
    """ControlSpec::parse: `+`-separated actions, times in µs.
    Returns None on any malformed spec (the CLI rejects those)."""
    if not s:
        return None
    if s == "static":
        return static_spec()
    trace = []
    autoscaler = None
    try:
        for part in s.split("+"):
            if part.startswith("auto:"):
                fields = part[len("auto:"):].split(":")
                if len(fields) != 4 or autoscaler is not None:
                    return None
                initial = int(fields[0])
                min_s, max_s = fields[1].split("-")
                low_us = float(fields[2])
                high_us = float(fields[3])
                autoscaler = {
                    "initial": initial,
                    "min_active": int(min_s),
                    "max_active": int(max_s),
                    "low_s": low_us * 1e-6,
                    "high_s": high_us * 1e-6,
                }
                continue
            if "@" not in part:
                return None
            head, at_us = part.rsplit("@", 1)
            at_us = float(at_us)
            if not (math.isfinite(at_us) and at_us >= 0.0):
                return None
            if head == "restore":
                action = ("restore",)
            else:
                if ":" not in head:
                    return None
                verb, arg = head.split(":", 1)
                if verb == "leave":
                    action = ("leave", int(arg))
                elif verb == "join":
                    action = ("join", int(arg))
                elif verb == "rankfail":
                    action = ("rankfail", int(arg))
                elif verb == "degrade":
                    factor = float(arg)
                    if not (factor > 0.0 and math.isfinite(factor)):
                        return None
                    action = ("degrade", factor)
                else:
                    return None
            trace.append((at_us * 1e-6, action))
    except ValueError:
        return None
    return {"key": s, "trace": trace, "autoscaler": autoscaler}


# ------------------------------------------------ control campaign


def default_control_cfg():
    return {
        "ranks": 4,
        "timesteps": 8,
        "policy": LEAST_OUTSTANDING,
        "oversub": 2.0,
        "seed": 42,
    }


def control_cells(cfg):
    """ControlCampaignConfig::cells: (label, topology, spec)."""
    keys = [
        ("local/static", "local", "static"),
        ("local/leave", "local", "leave:0@10300"),
        ("pooled/static", "pooled", "static"),
        ("pooled/leave", "pooled", "leave:0@10300"),
        ("pooled/degrade", "pooled", "degrade:0.25@6000+restore@20000"),
        ("pooled/rankfail", "pooled", "rankfail:1@10000"),
        ("pooled/auto", "pooled", "auto:2:1-4:100:1000"),
    ]
    return [(label, topology, parse_control(key)) for label, topology, key in keys]


def run_control_cell(topology, ctl, cfg):
    # same device count in and out of the pool: Fleet::Mixed{gpus:
    # ranks, rdus: 0}, so the loss cells compare like against like
    fleet = ("mixed", cfg["ranks"], 0)
    backends, (hermit_tier, mir_tier) = build_fleet(
        topology, cfg["ranks"], Link.infiniband_cx6(), fleet)
    sim_cfg = {
        "ranks": cfg["ranks"], "timesteps": cfg["timesteps"],
        "compute_s": 2e-3, "compute_jitter_s": 0.0,
        "requests_per_step": 6, "models": 8,
        "samples_per_request": (2, 3), "mir_every": 0, "mir_samples": 512,
        "overlap": 0.0, "swap_s": 0.0, "residency_slots": 4,
        "batching": None, "seed": cfg["seed"],
    }
    spec = build_fabric_spec(topology, cfg["ranks"], cfg["oversub"], fleet)
    fabric = FabricLayer(spec[0], spec[1], len(backends)) if spec else None
    sim = CogSim(backends, cfg["policy"], sim_cfg, hermit_tier, mir_tier, fabric)
    if not is_static(ctl):
        sim.with_control(ctl["trace"], ctl["autoscaler"])
    sim.run_to_completion()
    return sim


def run_control_campaign(cfg):
    cells = []
    for label, topology, ctl in control_cells(cfg):
        sim = run_control_cell(topology, ctl, cfg)
        cells.append({
            "label": label, "topology": topology, "control": ctl,
            "summary": sim.summary(), "sim": sim,
        })
    return {"config": cfg, "cells": cells}


def cell(result, label):
    for c in result["cells"]:
        if c["label"] == label:
            return c
    raise KeyError(f"control campaign has no cell {label!r}")


def loss_ratio(result, topology_key):
    stat = cell(result, f"{topology_key}/static")
    loss = cell(result, f"{topology_key}/leave")
    return (loss["summary"]["time_to_solution_s"]
            / stat["summary"]["time_to_solution_s"])


def autoscaler_factor(result):
    return (cell(result, "pooled/auto")["summary"]["time_to_solution_s"]
            / cell(result, "pooled/static")["summary"]["time_to_solution_s"])


# ------------------------------------------------------------- JSON


def control_cell_json(c):
    s = c["summary"]
    lat = s["latency"]
    return {
        "label": c["label"],
        "topology": c["topology"],
        "control": c["control"]["key"],
        "summary": {
            "tts_us": us(s["time_to_solution_s"]),
            "requests": float(s["requests"]),
            "submitted": float(s["submitted"]),
            "retries": float(s["retries"]),
            "failed": float(s["failed"]),
            "rank_restarts": float(s["rank_restarts"]),
            "mean_active_backends": fixed3(s["mean_active_backends"]),
            "request_p50_us": us(lat["p50_s"]),
            "request_p99_us": us(lat["p99_s"]),
            "total_queue_us": us(s["total_queue_s"]),
            "total_network_us": us(s["total_network_s"]),
        },
    }


def control_campaign_json(result):
    cfg = result["config"]
    ll = loss_ratio(result, "local")
    lp = loss_ratio(result, "pooled")
    auto = autoscaler_factor(result)
    return {
        "config": {
            "ranks": float(cfg["ranks"]),
            "timesteps": float(cfg["timesteps"]),
            "policy": cfg["policy"],
            "oversub": fixed3(cfg["oversub"]),
            "seed": float(cfg["seed"]),
        },
        "cells": [control_cell_json(c) for c in result["cells"]],
        "headline": {
            "loss_ratio_local": fixed3(ll),
            "loss_ratio_pooled": fixed3(lp),
            "pooled_degrades_more_gracefully": lp < ll,
            "autoscaler_factor": fixed3(auto),
            "autoscaler_bound": fixed3(AUTOSCALER_BOUND),
            "autoscaler_within_bound": auto <= AUTOSCALER_BOUND,
        },
    }
