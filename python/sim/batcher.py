"""coordinator::batcher transliteration.

Instants are integer nanoseconds from an epoch at 0; Duration
conversions mirror Rust's `from_secs_f64` (nearest ns, ties to even)
and `as_secs_f64` exactly — the eventsim BatchStage's tie-breaking
contract depends on this quantisation.
"""


class PendingRequest:
    __slots__ = ("id", "samples", "arrived_ns")

    def __init__(self, id_, samples, arrived_ns):
        self.id = id_
        self.samples = samples
        self.arrived_ns = arrived_ns


class Batch:
    __slots__ = ("instance", "requests", "total_samples")

    def __init__(self, instance, requests, total_samples):
        self.instance = instance
        self.requests = requests
        self.total_samples = total_samples


class DynamicBatcher:
    """All requests are Priority::Critical in the event engines, so
    the priority distinction collapses to a single max_wait."""

    def __init__(self, target_batch, max_wait_ns, max_batch):
        assert max_batch >= target_batch
        self.target_batch = target_batch
        self.max_wait_ns = max_wait_ns
        self.max_batch = max_batch
        self.queues = {}          # instance -> list[PendingRequest]
        self.queued_samples = {}  # instance -> int

    def enqueue(self, instance, req):
        self.queued_samples[instance] = self.queued_samples.get(instance, 0) + req.samples
        self.queues.setdefault(instance, []).append(req)

    def queued(self, instance):
        return self.queued_samples.get(instance, 0)

    def _queue_deadline(self, q):
        if not q:
            return None
        return min(r.arrived_ns + self.max_wait_ns for r in q)

    def _queue_size_ready(self, instance, q):
        return bool(q) and self.queued(instance) >= self.target_batch

    def _queue_ready(self, instance, q, now_ns):
        if self._queue_size_ready(instance, q):
            return True
        d = self._queue_deadline(q)
        return d is not None and now_ns >= d

    def has_ready(self, now_ns):
        return any(self._queue_ready(i, q, now_ns) for i, q in self.queues.items())

    def has_size_ready(self):
        return any(self._queue_size_ready(i, q) for i, q in self.queues.items())

    def next_deadline(self, now_ns):
        if self.has_ready(now_ns):
            return None
        ds = [d for d in (self._queue_deadline(q) for q in self.queues.values())
              if d is not None]
        return min(ds) if ds else None

    def _drain_picked(self, now_ns):
        picked = []
        for inst, q in self.queues.items():
            if now_ns is None:
                ready = self._queue_size_ready(inst, q)
            else:
                ready = self._queue_ready(inst, q, now_ns)
            if ready:
                # all requests are critical: (False, name) sort key
                picked.append((False, inst))
        picked.sort()
        return [self._drain_instance(inst) for _, inst in picked]

    def drain_ready(self, now_ns):
        return self._drain_picked(now_ns)

    def drain_size_ready(self):
        return self._drain_picked(None)

    def _drain_instance(self, instance):
        q = self.queues[instance]
        requests = []
        total = 0
        while q:
            front = q[0]
            if requests and total + front.samples > self.max_batch:
                break
            q.pop(0)
            total += front.samples
            requests.append(front)
        self.queued_samples[instance] -= total
        return Batch(instance, requests, total)
