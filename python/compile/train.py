"""Train the MIR autoencoder on synthetic material interfaces.

The paper's MIR model is a *trained* reconstruction network; random
weights only validate plumbing.  This script trains it for a few
hundred steps on the same synthetic volume-fraction interface
distribution the workload generator emits, logs the loss curve, and
(with ``--emit``) replaces the served weights + golden self-check so
the Rust stack serves the trained model.

Adam is implemented in-line (no optax in the build image).  Training
differentiates the pure-jnp reference forward — it computes the same
function as the Pallas forward (pytest asserts 1e-4 agreement), and
lowering/serving still use the Pallas path.

Usage:
    python -m compile.train [--steps 300] [--batch 32] [--emit]
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .models import mir
from .models.common import flat_arrays


def make_batch(rng: np.random.Generator, batch: int) -> np.ndarray:
    """Synthetic interface images (same family as mir.sample_input)."""
    seed = int(rng.integers(0, 2**31 - 1))
    return mir.sample_input(batch, seed=seed)


def loss_fn(params, x):
    """Binary cross-entropy between reconstruction and input — the
    natural loss for volume fractions in [0, 1]."""
    recon = mir.forward_ref(x, *params)
    eps = 1e-6
    recon = jnp.clip(recon, eps, 1.0 - eps)
    bce = -(x * jnp.log(recon) + (1.0 - x) * jnp.log(1.0 - recon))
    return jnp.mean(bce)


def adam_init(params):
    return (
        [jnp.zeros_like(p) for p in params],  # m
        [jnp.zeros_like(p) for p in params],  # v
    )


@jax.jit
def train_step(params, m, v, step, x, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(loss_fn)(params, x)
    new_params, new_m, new_v = [], [], []
    t = step + 1
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * jnp.square(g)
        m_hat = mi / (1 - b1**t)
        v_hat = vi / (1 - b2**t)
        new_params.append(p - lr * m_hat / (jnp.sqrt(v_hat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, loss


def train(steps: int = 300, batch: int = 32, seed: int = 0, log_every: int = 25):
    """Run training; returns (trained flat params, loss curve)."""
    rng = np.random.default_rng(seed)
    named = mir.init_params(seed)
    params = [jnp.asarray(a) for a in flat_arrays(named)]
    m, v = adam_init(params)

    curve = []
    t0 = time.time()
    for step in range(steps):
        x = jnp.asarray(make_batch(rng, batch))
        params, m, v, loss = train_step(params, m, v, step, x)
        curve.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"step {step:>4d}  bce {float(loss):.4f}  ({time.time() - t0:.1f}s)",
                file=sys.stderr,
            )
    names = [n for n, _ in named]
    return names, params, curve


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--emit",
        action="store_true",
        help="overwrite the served mir weights + golden self-check",
    )
    args = ap.parse_args()

    names, params, curve = train(args.steps, args.batch, args.seed)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    # loss curve (EXPERIMENTS.md §Training)
    csv = "step,bce\n" + "\n".join(f"{i},{l}" for i, l in enumerate(curve))
    (out / "mir_training_loss.csv").write_text(csv)
    print(f"wrote {out / 'mir_training_loss.csv'}", file=sys.stderr)

    np_params = [np.asarray(p) for p in params]
    np.savez(out / "mir_trained.weights.npz", **dict(zip(names, np_params)))
    print(f"wrote {out / 'mir_trained.weights.npz'}", file=sys.stderr)

    if args.emit:
        # serve the trained weights: weights are runtime arguments, so
        # only the npz and the golden vectors change — no re-lowering.
        np.savez(out / "mir.weights.npz", **dict(zip(names, np_params)))
        x_check = mir.sample_input(1, seed=2024)
        y_check = np.asarray(mir.forward(jnp.asarray(x_check), *params))
        np.savez(out / "mir.selfcheck.npz", x=x_check, y=y_check)
        print("emitted trained weights into mir.weights.npz (+selfcheck)", file=sys.stderr)

    print(f"final bce: {curve[-1]:.4f} (from {curve[0]:.4f})")


if __name__ == "__main__":
    main()
