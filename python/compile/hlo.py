"""StableHLO -> HLO-text conversion helper.

HLO *text* (not a serialized HloModuleProto) is the interchange format
between the JAX compile path and the Rust runtime: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects with
``proto.id() <= INT_MAX``.  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

from jax._src.lib import xla_client as xc


def lowered_to_hlo_text(lowered) -> str:
    """Convert ``jax.jit(f).lower(...)`` output to XLA HLO text.

    Lowered with ``return_tuple=True`` -- the Rust side unwraps the
    1-tuple with ``Literal::to_tuple1``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
