"""Hermit: the NLTE collisional-radiative atomic-physics surrogate.

Paper §IV-A (after Kluth et al., "Deep learning for NLTE spectral
opacities", PoP 2020): 21 fully-connected layers in three
sub-structures --

  * encoder : 4 layers, max hidden width 19, input 42 values;
  * DJINN   : 11 layers widening to a maximum of 2050 neurons
              (decision-tree-initialised trunk);
  * decoder : 6 layers, max hidden width 27.

Total ~2.8 M parameters.  ``tests/test_hermit.py`` asserts the layer
count (21) and the parameter budget.

The Pallas forward runs each sub-structure as ONE fused
:func:`djinn_block.djinn_chain` kernel (three kernel launches per
inference instead of 21 + 21 bias/activation launches -- the TPU-shaped
version of the paper's TensorRT+CUDA-Graphs configuration).  The DJINN
trunk's fused VMEM footprint is ~11.2 MB of weights + one activation
tile, inside the 14 MB planner budget (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import djinn_block, ref
from .common import Param, ParamBuilder

INPUT_SIZE = 42
OUTPUT_SIZE = 30  # spectral-opacity output bins

# Layer widths per sub-structure (21 weight layers total: 4 + 11 + 6).
ENCODER_WIDTHS = [INPUT_SIZE, 19, 17, 13, 10]
DJINN_WIDTHS = [10, 12, 16, 24, 32, 48, 64, 128, 256, 512, 1024, 2050]
DECODER_WIDTHS = [2050, 27, 27, 27, 27, 27, OUTPUT_SIZE]

INPUT_SHAPE = (INPUT_SIZE,)
OUTPUT_SHAPE = (OUTPUT_SIZE,)
PARAM_COUNT_RANGE = (2_700_000, 3_000_000)  # "2.8M parameters"
N_LAYERS = (len(ENCODER_WIDTHS) - 1) + (len(DJINN_WIDTHS) - 1) + (len(DECODER_WIDTHS) - 1)

# relu everywhere except the final (regression) layer.
_ENC_ACTS = ("relu",) * 4
_DJINN_ACTS = ("relu",) * 11
_DEC_ACTS = ("relu",) * 5 + (None,)


def init_params(seed: int = 0) -> List[Param]:
    """Deterministic He-initialised parameters, AOT calling order."""
    pb = ParamBuilder(seed)
    for i in range(len(ENCODER_WIDTHS) - 1):
        pb.dense(f"enc{i}", ENCODER_WIDTHS[i], ENCODER_WIDTHS[i + 1])
    for i in range(len(DJINN_WIDTHS) - 1):
        pb.dense(f"djinn{i}", DJINN_WIDTHS[i], DJINN_WIDTHS[i + 1])
    for i in range(len(DECODER_WIDTHS) - 1):
        pb.dense(f"dec{i}", DECODER_WIDTHS[i], DECODER_WIDTHS[i + 1])
    return pb.params


def _split(flat: Tuple[jnp.ndarray, ...]) -> Tuple[tuple, tuple, tuple]:
    """Split the flat (w, b, w, b, ...) list into the 3 sub-structures."""
    n_enc = 2 * (len(ENCODER_WIDTHS) - 1)
    n_djinn = 2 * (len(DJINN_WIDTHS) - 1)
    enc = tuple(flat[:n_enc])
    djinn = tuple(flat[n_enc : n_enc + n_djinn])
    dec = tuple(flat[n_enc + n_djinn :])
    return enc, djinn, dec


_ALL_ACTS = _ENC_ACTS + _DJINN_ACTS + _DEC_ACTS
_ALL_WIDTHS = ENCODER_WIDTHS + DJINN_WIDTHS[1:] + DECODER_WIDTHS[1:]


def forward(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
    """Pallas forward.

    When the whole 21-layer parameter set fits the VMEM budget
    (~13.6 MB — it does), the model runs as ONE fused-chain kernel:
    a single launch per mini-batch tile, weights staged through VMEM
    once, zero HBM round-trips between layers.  §Perf measured this
    11 % faster than the three-chain split at batch 256 and equal to
    the pure-jnp reference across the ladder.  Falls back to one
    chain per sub-structure if a future variant outgrows VMEM.
    """
    if djinn_block.fits_vmem(_ALL_WIDTHS):
        return djinn_block.djinn_chain(x, flat, activations=_ALL_ACTS)
    enc, djinn, dec = _split(flat)
    h = djinn_block.djinn_chain(x, enc, activations=_ENC_ACTS)
    h = djinn_block.djinn_chain(h, djinn, activations=_DJINN_ACTS)
    return djinn_block.djinn_chain(h, dec, activations=_DEC_ACTS)


def forward_ref(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle with identical parameters."""
    enc, djinn, dec = _split(flat)
    h = ref.chain(x, enc, _ENC_ACTS)
    h = ref.chain(h, djinn, _DJINN_ACTS)
    return ref.chain(h, dec, _DEC_ACTS)


def sample_input(batch: int, seed: int = 1) -> np.ndarray:
    """A synthetic NLTE state vector batch (temperature/density/field
    features are O(1) after the usual log-normalisation)."""
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 1.0, size=(batch, INPUT_SIZE)).astype(np.float32)
