"""Layer-2 JAX surrogate models (Hermit + MIR).

Each model module exposes:
  - ``init_params(seed) -> list[(name, np.ndarray)]`` deterministic,
    ordered parameter list (the order is the AOT calling convention).
  - ``forward(x, *flat) -> y``   Pallas-kernel forward (what we ship).
  - ``forward_ref(x, *flat) -> y`` pure-jnp oracle (pytest only).
  - ``INPUT_SHAPE / OUTPUT_SHAPE`` per-sample shapes.
  - ``PARAM_COUNT_RANGE`` the paper's stated parameter budget.
"""

from . import hermit, mir  # noqa: F401

REGISTRY = {
    "hermit": hermit,
    "mir": mir,
    "mir_noln": mir.NOLN,
}
