"""Shared parameter-initialisation utilities for the surrogate models.

Parameters are created as *numpy* arrays (not jax) so aot.py can write
them straight into ``artifacts/<model>.weights.npz`` with deterministic
bytes; jax only sees them as traced arguments.  Names are zero-padded
(``p000``, ``p001`` ...) so lexicographic order == calling convention,
which is what the Rust loader relies on (`read_npz_by_name`).
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

Param = Tuple[str, np.ndarray]


class ParamBuilder:
    """Accumulates named parameters in calling-convention order."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)
        self.params: List[Param] = []

    def _add(self, tag: str, arr: np.ndarray) -> np.ndarray:
        name = f"p{len(self.params):03d}_{tag}"
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        self.params.append((name, arr))
        return arr

    def dense(self, tag: str, d_in: int, d_out: int) -> Tuple[np.ndarray, np.ndarray]:
        """He-initialised (w, b) pair for a relu FC layer."""
        scale = np.sqrt(2.0 / d_in)
        w = self._add(f"{tag}_w", self.rng.normal(0.0, scale, size=(d_in, d_out)))
        b = self._add(f"{tag}_b", np.zeros((d_out,)))
        return w, b

    def conv(self, tag: str, c_in: int, c_out: int, k: int = 3) -> Tuple[np.ndarray, np.ndarray]:
        """He-initialised (kernel, bias) for a k x k conv."""
        scale = np.sqrt(2.0 / (k * k * c_in))
        w = self._add(f"{tag}_k", self.rng.normal(0.0, scale, size=(k, k, c_in, c_out)))
        b = self._add(f"{tag}_b", np.zeros((c_out,)))
        return w, b

    def bias(self, tag: str, d: int) -> np.ndarray:
        """A stand-alone bias (used by tied-weight decoder layers)."""
        return self._add(f"{tag}_b", np.zeros((d,)))

    def ln(self, tag: str, d: int) -> Tuple[np.ndarray, np.ndarray]:
        """Layernorm (gamma, beta)."""
        g = self._add(f"{tag}_g", np.ones((d,)))
        b = self._add(f"{tag}_b", np.zeros((d,)))
        return g, b


def param_count(params: List[Param]) -> int:
    return sum(int(a.size) for _, a in params)


def flat_arrays(params: List[Param]) -> List[np.ndarray]:
    return [a for _, a in params]
