"""MIR: the material-interface-reconstruction surrogate.

Paper §IV-B: a convolutional autoencoder that reconstructs continuous
material boundaries from per-zone volume-fraction images --

  * 4 convolution layers with pooling, layernorm after every conv;
  * 3 fully-connected layers, two of which touch 4608 neurons;
  * transposed-convolution decoder whose weights are TIED to the
    encoder convs (regularisation);
  * ~700 K parameters total.

§IV-C notes the model was re-shaped for the dataflow architecture
(batchnorm -> layernorm, shrunken FC layers); we implement that final
published shape.  Fig. 20 uses a no-layernorm variant so the model
compiles optimally on both architectures -- exposed here as ``NOLN``.

Concrete geometry (input 48x48 volume-fraction image):
  enc: conv 1->16  +pool -> 24x24 | conv 16->32 +pool -> 12x12
     | conv 32->64 +pool ->  6x6  | conv 64->128 (no pool)
  flatten 6*6*128 = 4608  (the paper's FC width)
  fc: 4608 -> 64 -> 64 -> 4608 (3 FC layers, two touching 4608)
  dec: tied convT 128->64 (s1) | 64->32 (s2) | 32->16 (s2) | 16->1 (s2)
  output 48x48 sigmoid (volume fraction in [0,1]).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import conv2d, fused_linear, layernorm, ref
from .common import Param, ParamBuilder

IMG = 48
INPUT_SHAPE = (IMG, IMG, 1)
OUTPUT_SHAPE = (IMG, IMG, 1)
PARAM_COUNT_RANGE = (620_000, 780_000)  # "700K parameters"

CHANNELS = [1, 16, 32, 64, 128]  # encoder conv channel progression
POOLED = [True, True, True, False]  # pool after convs 1-3 only
FLAT = 6 * 6 * 128  # == 4608, the paper's FC width
BOTTLENECK = 64


def init_params(seed: int = 0, *, use_layernorm: bool = True) -> List[Param]:
    """Deterministic parameters in AOT calling order.

    Order: 4x (conv k, conv b) [+ (ln g, ln b)], 3x (fc w, fc b),
    4x decoder bias (kernels are tied to the encoder convs).
    """
    pb = ParamBuilder(seed)
    for i in range(4):
        pb.conv(f"conv{i}", CHANNELS[i], CHANNELS[i + 1])
        if use_layernorm:
            pb.ln(f"ln{i}", CHANNELS[i + 1])
    pb.dense("fc0", FLAT, BOTTLENECK)
    pb.dense("fc1", BOTTLENECK, BOTTLENECK)
    pb.dense("fc2", BOTTLENECK, FLAT)
    for i in reversed(range(4)):
        pb.bias(f"dect{i}", CHANNELS[i])
    return pb.params


def _unpack(flat: Tuple[jnp.ndarray, ...], use_layernorm: bool):
    """Split the flat argument list into structured pieces."""
    i = 0
    convs, lns = [], []
    for _ in range(4):
        convs.append((flat[i], flat[i + 1]))
        i += 2
        if use_layernorm:
            lns.append((flat[i], flat[i + 1]))
            i += 2
    fcs = [(flat[i], flat[i + 1]), (flat[i + 2], flat[i + 3]), (flat[i + 4], flat[i + 5])]
    i += 6
    dec_biases = list(flat[i : i + 4])  # order: dect3, dect2, dect1, dect0
    return convs, lns, fcs, dec_biases


def _forward(
    x: jnp.ndarray,
    flat: Tuple[jnp.ndarray, ...],
    *,
    use_layernorm: bool,
    use_pallas: bool,
) -> jnp.ndarray:
    """Shared forward over the Pallas kernels or the jnp oracles."""
    convs, lns, fcs, dec_biases = _unpack(flat, use_layernorm)
    conv_f = conv2d.conv2d_same if use_pallas else ref.conv2d_same
    convt_f = conv2d.conv2d_transpose_tied if use_pallas else ref.conv2d_transpose_tied
    pool_f = conv2d.maxpool2x2 if use_pallas else ref.maxpool2x2
    ln_f = layernorm.layernorm if use_pallas else ref.layernorm
    lin_f = fused_linear.fused_linear if use_pallas else ref.linear

    # ---- encoder ----
    h = x
    for i in range(4):
        k, b = convs[i]
        if use_pallas:
            h = conv_f(h, k, b, activation="relu")
        else:
            h = conv_f(h, k, b, "relu")
        if use_layernorm:
            g, bb = lns[i]
            h = ln_f(h, g, bb)
        if POOLED[i]:
            h = pool_f(h)

    # ---- FC stack (4608 -> 64 -> 64 -> 4608) ----
    batch = h.shape[0]
    h = h.reshape(batch, FLAT)
    for j, (w, b) in enumerate(fcs):
        act = "relu"
        if use_pallas:
            h = lin_f(h, w, b, activation=act)
        else:
            h = lin_f(h, w, b, act)
    h = h.reshape(batch, 6, 6, 128)

    # ---- tied-weight transposed-conv decoder ----
    # dec_biases order matches reversed(range(4)): conv3 first.
    for idx, layer in enumerate(reversed(range(4))):
        k, _ = convs[layer]
        stride = 2 if POOLED[layer] else 1
        act: Optional[str] = "relu" if layer != 0 else "sigmoid"
        if use_pallas:
            h = convt_f(h, k, dec_biases[idx], stride=stride, activation=act)
        else:
            h = convt_f(h, k, dec_biases[idx], stride, act)
    return h


def forward(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
    """Pallas forward (layernorm variant)."""
    return _forward(x, flat, use_layernorm=True, use_pallas=True)


def forward_ref(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle (layernorm variant)."""
    return _forward(x, flat, use_layernorm=True, use_pallas=False)


def sample_input(batch: int, seed: int = 1) -> np.ndarray:
    """Synthetic volume-fraction images: a random half-plane interface
    smoothed over the zone grid -- the same structure MIR sees from the
    hydro code (mixed zones near a material boundary)."""
    rng = np.random.default_rng(seed)
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    imgs = np.empty((batch, IMG, IMG, 1), dtype=np.float32)
    for i in range(batch):
        theta = rng.uniform(0, 2 * np.pi)
        offset = rng.uniform(0.3, 0.7)
        d = (np.cos(theta) * xs + np.sin(theta) * ys) - offset
        imgs[i, :, :, 0] = 1.0 / (1.0 + np.exp(-d * rng.uniform(8, 24)))
    return imgs


class _NoLayernormVariant:
    """Fig-20 variant: identical geometry, layernorm removed so the
    model 'compiles optimally on both architectures' (paper §V-E)."""

    __name__ = "mir_noln"
    INPUT_SHAPE = INPUT_SHAPE
    OUTPUT_SHAPE = OUTPUT_SHAPE
    PARAM_COUNT_RANGE = (620_000, 780_000)

    @staticmethod
    def init_params(seed: int = 0) -> List[Param]:
        return init_params(seed, use_layernorm=False)

    @staticmethod
    def forward(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
        return _forward(x, flat, use_layernorm=False, use_pallas=True)

    @staticmethod
    def forward_ref(x: jnp.ndarray, *flat: jnp.ndarray) -> jnp.ndarray:
        return _forward(x, flat, use_layernorm=False, use_pallas=False)

    @staticmethod
    def sample_input(batch: int, seed: int = 1) -> np.ndarray:
        return sample_input(batch, seed)


NOLN = _NoLayernormVariant()
