"""AOT lowering: every (model, mini-batch) pair -> HLO text + weights.

Run once by ``make artifacts``.  Outputs, per model:

  artifacts/<model>_b<batch>.hlo.txt   -- HLO text, signature
                                          (x, p000, p001, ...) -> (y,)
  artifacts/<model>.weights.npz        -- named parameter arrays
  artifacts/manifest.json              -- shapes, dtypes, batch ladder,
                                          param order, sha256 of weights

The weights are *arguments*, not baked constants: the Rust runtime
uploads them to device buffers once (``PjRtBuffer::read_npz_by_name``)
and reuses them across every request via ``execute_b`` -- Python never
appears on the request path.

The mini-batch ladder mirrors the paper's tested sizes (powers of 4
from 1) capped per model: the CPU PJRT backend executes these for real,
so MIR's conv stack gets a shorter ladder than Hermit's FC stack.  The
device performance models in rust/src/devices cover the paper's full
1..32K range analytically.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .hlo import lowered_to_hlo_text
from .models import REGISTRY
from .models.common import flat_arrays

# Default mini-batch ladders (real CPU execution -- keep tractable).
# Hermit gets a dense powers-of-2 ladder: the Hydra request mix is
# dominated by small odd-sized requests and the ablation bench showed
# a powers-of-4 ladder wasting 69% of executed samples as padding vs
# 38% for powers-of-2 (EXPERIMENTS.md SPerf).  Executables are cheap
# (one PJRT compile each at build time).
DEFAULT_BATCHES = {
    "hermit": [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024],
    "mir": [1, 4, 16, 64],
    "mir_noln": [1, 4, 16, 64],
}

DTYPE = "f32"  # CPU PJRT has no fp16 kernels; see DESIGN.md substitutions.


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def lower_model(model_name: str, batches, out_dir: Path, seed: int = 0) -> dict:
    """Lower one model at every batch size; write artifacts; return its
    manifest entry."""
    model = REGISTRY[model_name]
    params = model.init_params(seed)
    flat = flat_arrays(params)

    # ---- weights.npz (arrays keyed by calling-convention name) ----
    weights_path = out_dir / f"{model_name}.weights.npz"
    np.savez(weights_path, **{name: arr for name, arr in params})

    entry = {
        "input_shape": list(model.INPUT_SHAPE),
        "output_shape": list(model.OUTPUT_SHAPE),
        "dtype": DTYPE,
        "params": [
            {"name": name, "shape": list(arr.shape)} for name, arr in params
        ],
        "weights_file": weights_path.name,
        "weights_sha256": _sha256(weights_path),
        "batches": [],
        "param_count": int(sum(a.size for a in flat)),
        "selfcheck": None,  # filled in below
    }

    # ---- golden self-check vectors (cross-language numerics test) ----
    # rust/tests/runtime.rs executes the artifacts and compares against
    # these exact outputs computed by the Python (Pallas) forward.
    check_batch = min(batches)
    x_check = model.sample_input(check_batch, seed=2024)
    y_check = np.asarray(
        model.forward(jnp.asarray(x_check), *[jnp.asarray(a) for a in flat])
    )
    check_path = out_dir / f"{model_name}.selfcheck.npz"
    np.savez(check_path, x=x_check, y=y_check)
    entry["selfcheck"] = {"file": check_path.name, "batch": check_batch}

    param_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in flat]
    for batch in batches:
        t0 = time.time()
        x_spec = jax.ShapeDtypeStruct((batch, *model.INPUT_SHAPE), jnp.float32)
        lowered = jax.jit(model.forward).lower(x_spec, *param_specs)
        text = lowered_to_hlo_text(lowered)
        hlo_path = out_dir / f"{model_name}_b{batch}.hlo.txt"
        hlo_path.write_text(text)
        entry["batches"].append(
            {"batch": batch, "hlo_file": hlo_path.name, "hlo_bytes": len(text)}
        )
        print(
            f"  {model_name} b={batch:<5d} -> {hlo_path.name} "
            f"({len(text) / 1e6:.1f} MB, {time.time() - t0:.1f}s)",
            file=sys.stderr,
        )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models", nargs="*", default=list(DEFAULT_BATCHES), help="models to lower"
    )
    ap.add_argument(
        "--max-batch", type=int, default=None,
        help="truncate every ladder at this mini-batch size",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"dtype": DTYPE, "seed": args.seed, "models": {}}
    for name in args.models:
        batches = DEFAULT_BATCHES[name]
        if args.max_batch is not None:
            batches = [b for b in batches if b <= args.max_batch]
        print(f"lowering {name} at batches {batches}", file=sys.stderr)
        manifest["models"][name] = lower_model(name, batches, out_dir, args.seed)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}", file=sys.stderr)


if __name__ == "__main__":
    main()
