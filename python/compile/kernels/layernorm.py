"""Layer normalisation over the trailing axis as a Pallas kernel.

The MIR model applies layernorm after every convolution -- the paper's
§IV-C notes batchnorm was *replaced* by layernorm specifically to map
the model onto the dataflow architecture (batchnorm's cross-batch
reduction breaks a spatial pipeline; layernorm reduces within a single
sample).  The same property makes it trivially tileable here: the grid
walks batch-row tiles and each tile normalises independently.

Figure 10's TensorRT penalty comes from torch2trt's *unoptimised*
layernorm; fusing scale/shift into the normalisation pass is exactly
what this kernel does.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import _ceil_to, pick_block_m


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    """Normalise each row of the (bm, D) tile over D, then scale+shift."""
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    norm = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (norm * g_ref[...][None, :] + b_ref[...][None, :]).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_m", "interpret"))
def layernorm(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    eps: float = 1e-5,
    block_m: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """LayerNorm over the last axis of a 2-D or N-D input.

    N-D inputs are flattened to ``(rows, D)``, normalised over ``D``
    (the channel axis for NHWC conv outputs), and reshaped back.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    if gamma.shape != (d,) or beta.shape != (d,):
        raise ValueError(f"gamma/beta must be ({d},); got {gamma.shape}/{beta.shape}")
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    bm = block_m or pick_block_m(rows)
    mp = _ceil_to(rows, bm)
    x_p = jnp.pad(x2, ((0, mp - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), x.dtype),
        interpret=interpret,
    )(x_p, gamma, beta)
    return out[:rows].reshape(orig_shape)
