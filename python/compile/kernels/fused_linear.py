"""Fused linear layer: ``act(x @ w + b)`` as a single Pallas kernel.

This is the TensorRT-fusion analogue from the paper (DESIGN.md
§Hardware-Adaptation): on the GPU the vendor toolchain fuses the GEMM,
bias-add and activation into one kernel to cut launch overhead; here
the fusion is explicit.  The kernel is tiled for the MXU: the grid
walks (M/bm, N/bn) output tiles, the full contraction dimension K is
staged into VMEM per tile (all CogSim-surrogate layers have K <= 4608,
i.e. <= 2.4 MB per 128-wide tile at f32 -- well inside VMEM).

VMEM footprint per grid step (f32):
    bm*K (activations) + K*bn (weights) + bm*bn (output tile)
For the largest Hermit layer (K=1024, N=2050, bm=bn=128):
    128*1024*4 + 1024*128*4 + 128*128*4  ~= 1.1 MB.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes.  The systolic array is 128x128; the
# VPU lane structure is (8, 128).  bm is allowed to shrink to 8 for
# latency-bound small batches (the paper's key regime).
BM_DEFAULT = 128
BN_DEFAULT = 128


def _apply_activation(h: jnp.ndarray, activation: Optional[str]) -> jnp.ndarray:
    """Apply a named activation inside the kernel (fused epilogue)."""
    if activation is None or activation == "linear":
        return h
    if activation == "relu":
        return jnp.maximum(h, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(h)
    if activation == "tanh":
        return jnp.tanh(h)
    raise ValueError(f"unknown activation: {activation!r}")


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: Optional[str]):
    """One (bm, bn) output tile: full-K matmul + bias + activation.

    ``preferred_element_type=f32`` keeps the MXU accumulator at full
    precision even when inputs are bf16 (the paper runs BF16 on the
    RDU and FP16 on the GPUs; accumulation is always f32).
    """
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    o_ref[...] = _apply_activation(acc, activation).astype(o_ref.dtype)


def _ceil_to(value: int, multiple: int) -> int:
    return ((value + multiple - 1) // multiple) * multiple


def pick_block_m(m: int) -> int:
    """Batch-block size: the exact row count, capped at 128.

    §Perf note: an earlier revision rounded up to the 8-row VPU
    sublane, but on the CPU-PJRT execution path the padded rows are
    *real* compute — at batch 1 that made the whole Hermit forward
    1.74x slower than the pure-jnp reference (EXPERIMENTS.md §Perf).
    Exact-size blocks recover parity; on a real TPU, Mosaic pads
    sub-sublane tiles in-register, so nothing is lost there either.
    """
    return min(BM_DEFAULT, max(1, m))


def pick_block_n(n: int) -> int:
    """Output-feature block: multiple of the 128 MXU lane, capped at 128."""
    return min(BN_DEFAULT, _ceil_to(n, 128))


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "interpret")
)
def fused_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    activation: Optional[str] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Args:
      x: ``(M, K)`` activations.
      w: ``(K, N)`` weights.
      b: ``(N,)`` bias.
      activation: one of ``None | "relu" | "sigmoid" | "tanh"``.
      block_m / block_n: tile overrides (defaults are MXU-aligned).
      interpret: must stay True for CPU-PJRT execution (Mosaic
        custom-calls cannot run on the CPU plugin).

    Returns:
      ``(M, N)`` output, same dtype as ``x``.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x{x.shape} @ w{w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    if m == 0:
        # A fully-drained batcher can legally issue an empty batch.
        return jnp.zeros((0, n), dtype=x.dtype)

    bm = block_m or pick_block_m(m)
    bn = block_n or pick_block_n(n)

    # Zero-pad M and N up to tile multiples; K is staged whole.  The
    # zero rows/cols are sliced off below, so they never alias output.
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    x_p = jnp.pad(x, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w, ((0, 0), (0, np_ - n)))
    b_p = jnp.pad(b, (0, np_ - n))

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_fused_linear_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(x_p, w_p, b_p)
    return out[:m, :n]


def vmem_bytes(m: int, k: int, n: int, *, dtype_bytes: int = 4,
               block_m: Optional[int] = None, block_n: Optional[int] = None) -> int:
    """Estimated VMEM footprint of one grid step (for §Perf reporting)."""
    bm = block_m or pick_block_m(m)
    bn = block_n or pick_block_n(n)
    return dtype_bytes * (bm * k + k * bn + bm * bn + bn)
