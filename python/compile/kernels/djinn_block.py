"""Fused chain of fully-connected layers in one Pallas kernel.

The Hermit model's DJINN trunk is 11 narrow-to-wide FC layers.  Run
naively ("naive PyTorch" in the paper), every layer is a separate
kernel launch and every intermediate activation round-trips through
HBM -- exactly the overhead that makes small-mini-batch latency
CPU-bound in the paper's Figure 4.  This kernel is the CUDA-Graphs +
TensorRT analogue for TPU hardware: the *entire chain* is one kernel,
weights are staged into VMEM once per batch tile, and intermediate
activations live only in registers/VMEM.

VMEM budget: the sum of all DJINN weights is ~2.8 M f32 = 11.2 MB,
which fits a 16 MB VMEM alongside one (8..128, 2050) activation tile.
The chain builder checks the estimate and refuses to fuse beyond the
budget (callers then fall back to per-layer ``fused_linear``).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import _apply_activation, _ceil_to, pick_block_m

# Conservative single-core VMEM budget (bytes) used by the fusion
# planner.  Real TPUv4 VMEM is 16 MiB/core; we leave headroom for the
# activation tile and double-buffering.
VMEM_BUDGET_BYTES = 14 * 1024 * 1024


def _chain_kernel(*refs, n_layers: int, activations: Tuple[Optional[str], ...]):
    """Kernel body: h = act_i(h @ w_i + b_i) for i in 0..n_layers.

    ``refs`` layout: (x_ref, w_0, b_0, w_1, b_1, ..., o_ref).
    All weight blocks are whole arrays (the chain is only fused when
    they fit VMEM together); only the batch dimension is tiled.
    """
    x_ref = refs[0]
    o_ref = refs[-1]
    h = x_ref[...]
    for i in range(n_layers):
        w = refs[1 + 2 * i][...]
        b = refs[2 + 2 * i][...]
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = _apply_activation(h + b[None, :], activations[i])
    o_ref[...] = h.astype(o_ref.dtype)


def chain_vmem_bytes(
    widths: Sequence[int], *, block_m: int, dtype_bytes: int = 4
) -> int:
    """VMEM estimate for a fused chain: all weights + widest activation."""
    weights = sum(widths[i] * widths[i + 1] + widths[i + 1] for i in range(len(widths) - 1))
    act = block_m * max(widths)
    return dtype_bytes * (weights + 2 * act)


def fits_vmem(widths: Sequence[int], *, block_m: int = 128) -> bool:
    """True when the whole chain can be fused within the VMEM budget."""
    return chain_vmem_bytes(widths, block_m=block_m) <= VMEM_BUDGET_BYTES


@functools.partial(
    jax.jit, static_argnames=("activations", "block_m", "interpret")
)
def djinn_chain(
    x: jnp.ndarray,
    params: Tuple[jnp.ndarray, ...],
    *,
    activations: Tuple[Optional[str], ...],
    block_m: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Run a fused FC chain ``act_i(h @ w_i + b_i)`` over batch tiles.

    Args:
      x: ``(M, d0)`` input activations.
      params: flat tuple ``(w_0, b_0, w_1, b_1, ...)`` with
        ``w_i: (d_i, d_{i+1})``, ``b_i: (d_{i+1},)``.
      activations: one name (or None) per layer.
      block_m: batch tile (default MXU-aligned via ``pick_block_m``).
      interpret: keep True for CPU-PJRT (see module docstring).

    Returns:
      ``(M, d_last)`` output.
    """
    if len(params) % 2 != 0:
        raise ValueError("params must be (w, b) pairs")
    n_layers = len(params) // 2
    if len(activations) != n_layers:
        raise ValueError(f"{len(activations)} activations for {n_layers} layers")

    widths = [x.shape[1]]
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        if w.shape[0] != widths[-1]:
            raise ValueError(f"layer {i}: w{w.shape} does not chain from {widths[-1]}")
        if b.shape != (w.shape[1],):
            raise ValueError(f"layer {i}: bias {b.shape} != ({w.shape[1]},)")
        widths.append(w.shape[1])

    m = x.shape[0]
    bm = block_m or pick_block_m(m)
    if not fits_vmem(widths, block_m=bm):
        raise ValueError(
            f"chain widths {widths} exceed VMEM budget "
            f"({chain_vmem_bytes(widths, block_m=bm)} > {VMEM_BUDGET_BYTES} B); "
            "split the chain or use per-layer fused_linear"
        )

    mp = _ceil_to(m, bm)
    x_p = jnp.pad(x, ((0, mp - m), (0, 0)))

    in_specs = [pl.BlockSpec((bm, widths[0]), lambda i: (i, 0))]
    for li in range(n_layers):
        d_in, d_out = widths[li], widths[li + 1]
        # Whole-array blocks: weights are broadcast to every batch tile.
        in_specs.append(pl.BlockSpec((d_in, d_out), lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec((d_out,), lambda i: (0,)))

    out = pl.pallas_call(
        functools.partial(
            _chain_kernel, n_layers=n_layers, activations=tuple(activations)
        ),
        grid=(mp // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, widths[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, widths[-1]), x.dtype),
        interpret=interpret,
    )(x_p, *params)
    return out[:m]
