"""Layer-1 Pallas kernels for the CogSim surrogate models.

Every kernel here is written for TPU-style hardware (VMEM scratchpad +
MXU systolic array) but lowered with ``interpret=True`` so the
resulting HLO runs on any PJRT backend, including the Rust CPU client
on the request path.  See DESIGN.md §Hardware-Adaptation for how the
paper's GPU/RDU concepts (TensorRT fusion, CUDA Graphs launch elision,
RDU micro-batches) map onto these kernels.

Kernels:
  - :mod:`fused_linear`  -- matmul + bias + activation in one kernel.
  - :mod:`djinn_block`   -- a fused *chain* of fully-connected layers
    (one HBM round-trip for the whole Hermit DJINN trunk).
  - :mod:`conv2d`        -- 3x3 SAME convolution as 9 shifted MXU matmuls.
  - :mod:`layernorm`     -- row-parallel two-pass layer normalisation.
  - :mod:`ref`           -- pure-jnp oracles used by pytest.
"""

from . import conv2d, djinn_block, fused_linear, layernorm, ref  # noqa: F401
