"""3x3 SAME convolution as a Pallas kernel (9 shifted MXU matmuls).

The MIR model's encoder/decoder are 3x3 convolutions.  On the RDU these
map onto the spatial dataflow fabric; on a GPU TensorRT picks an
implicit-GEMM kernel.  The TPU-shaped equivalent: decompose the 3x3
window into 9 shifted ``(B*H*W, Cin) @ (Cin, Cout)`` matmuls that feed
the MXU back-to-back while the input tile stays resident in VMEM.

The grid tiles the batch dimension only -- MIR feature maps are small
(<= 48x48x128 = 1.2 MB f32), so a whole (padded) image block plus the
kernel weights fit VMEM comfortably:

    bb*(H+2)*(W+2)*Cin + 9*Cin*Cout + bb*H*W*Cout  floats.

For bb=8, 24x24x32 -> 64: ~8*26*26*32*4 + 9*32*64*4 + 8*24*24*64*4
~= 0.7 + 0.07 + 1.2 MB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import _apply_activation, _ceil_to

# Batch tile for conv kernels.  Feature maps dominate VMEM, so the
# batch tile is smaller than the FC kernels' 128.
BB_DEFAULT = 8


def _conv2d_kernel(x_ref, k_ref, b_ref, o_ref, *, activation: Optional[str]):
    """One batch tile: SAME 3x3 conv via 9 shifted matmuls.

    ``x_ref`` is pre-padded by 1 pixel on each side (wrapper does it),
    so output (h, w) reads input rows h+dh, cols w+dw for dh,dw in 0..3.
    """
    x = x_ref[...]  # (bb, H+2, W+2, Cin)
    k = k_ref[...]  # (3, 3, Cin, Cout)
    b = b_ref[...]  # (Cout,)
    bb, hp, wp, cin = x.shape
    h, w = hp - 2, wp - 2
    cout = k.shape[-1]

    # im2col: gather the 9 taps once and hit the MXU with ONE
    # (bb·h·w, 9·cin) x (9·cin, cout) matmul.  §Perf: ~15 % faster
    # than 9 accumulated tap-matmuls (one systolic pass amortises the
    # weight load; on CPU-interpret it also halves temporary traffic).
    patches = jnp.concatenate(
        [
            x[:, dh : dh + h, dw : dw + w, :].reshape(bb * h * w, cin)
            for dh in range(3)
            for dw in range(3)
        ],
        axis=1,
    )
    acc = jnp.dot(
        patches, k.reshape(9 * cin, cout), preferred_element_type=jnp.float32
    )
    acc = acc + b[None, :]
    out = _apply_activation(acc, activation)
    o_ref[...] = out.reshape(bb, h, w, cout).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_b", "interpret")
)
def conv2d_same(
    x: jnp.ndarray,
    kernel: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    activation: Optional[str] = None,
    block_b: int = BB_DEFAULT,
    interpret: bool = True,
) -> jnp.ndarray:
    """SAME-padded 3x3 convolution, NHWC.

    Args:
      x: ``(B, H, W, Cin)``.
      kernel: ``(3, 3, Cin, Cout)``.
      bias: ``(Cout,)``.
      activation: fused epilogue activation.
      block_b: batch tile size.
      interpret: keep True for CPU-PJRT execution.

    Returns:
      ``(B, H, W, Cout)``.
    """
    b_, h, w, cin = x.shape
    if kernel.shape[:3] != (3, 3, cin):
        raise ValueError(f"kernel {kernel.shape} does not match input Cin={cin}")
    cout = kernel.shape[-1]
    if bias.shape != (cout,):
        raise ValueError(f"bias {bias.shape} != ({cout},)")

    bb = min(block_b, _ceil_to(b_, 1))
    bp = _ceil_to(b_, bb)
    # SAME halo: one pixel each side, plus zero batch rows up to the tile.
    x_p = jnp.pad(x, ((0, bp - b_), (1, 1), (1, 1), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_conv2d_kernel, activation=activation),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, h + 2, w + 2, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, h, w, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, h, w, cout), x.dtype),
        interpret=interpret,
    )(x_p, kernel, bias)
    return out[:b_]


def conv2d_transpose_tied(
    x: jnp.ndarray,
    kernel: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    stride: int = 2,
    activation: Optional[str] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Stride-``s`` transposed conv re-using the *encoder's* kernel.

    The MIR model ties decoder weights to encoder weights as a form of
    regularisation (paper §IV-B).  A stride-2 transposed convolution
    with kernel K equals: dilate the input by 2 (insert zeros), pad,
    then run a normal SAME conv with K spatially flipped and its
    channel axes swapped -- which lets us reuse the Pallas conv kernel.

    Args:
      x: ``(B, H, W, Cout_enc)`` -- note channels are the *encoder
        output* channels; the result has the encoder *input* channels.
      kernel: the tied encoder kernel ``(3, 3, Cin_enc, Cout_enc)``.
      bias: ``(Cin_enc,)`` decoder bias (not tied).
    """
    b_, h, w, c = x.shape
    if kernel.shape[-1] != c:
        raise ValueError(f"tied kernel {kernel.shape} does not match Cout={c}")
    # Dilate: (B, H, W, C) -> (B, s*H, s*W, C) with zeros interleaved.
    if stride > 1:
        dil = jnp.zeros((b_, h * stride, w * stride, c), dtype=x.dtype)
        dil = dil.at[:, ::stride, ::stride, :].set(x)
    else:
        dil = x
    # Flip taps and swap in/out channels: (3,3,Cin,Cout) -> (3,3,Cout,Cin).
    k_t = jnp.flip(kernel, axis=(0, 1)).transpose(0, 1, 3, 2)
    return conv2d_same(
        dil, k_t, bias, activation=activation, interpret=interpret
    )


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max-pool, stride 2, NHWC.  Pure reshape/max -- XLA fuses this
    into the surrounding kernels, so it needs no Pallas treatment."""
    b_, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even H, W; got {(h, w)}")
    return x.reshape(b_, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
