"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for pytest: each kernel in this package must
match its oracle to ~1e-5 (f32).  They are also the "naive" compute
path used to cross-check the full models (models/*.py build both a
Pallas forward and a ref forward from the same parameters).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def apply_activation(h: jnp.ndarray, activation: Optional[str]) -> jnp.ndarray:
    if activation is None or activation == "linear":
        return h
    if activation == "relu":
        return jnp.maximum(h, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(h)
    if activation == "tanh":
        return jnp.tanh(h)
    raise ValueError(f"unknown activation: {activation!r}")


def linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    activation: Optional[str] = None,
) -> jnp.ndarray:
    """Oracle for :func:`fused_linear.fused_linear`."""
    return apply_activation(x @ w + b[None, :], activation)


def chain(
    x: jnp.ndarray,
    params: Sequence[jnp.ndarray],
    activations: Sequence[Optional[str]],
) -> jnp.ndarray:
    """Oracle for :func:`djinn_block.djinn_chain`."""
    h = x
    for i, act in enumerate(activations):
        h = linear(h, params[2 * i], params[2 * i + 1], act)
    return h


def conv2d_same(
    x: jnp.ndarray,
    kernel: jnp.ndarray,
    bias: jnp.ndarray,
    activation: Optional[str] = None,
) -> jnp.ndarray:
    """Oracle for :func:`conv2d.conv2d_same` via lax.conv_general_dilated."""
    out = jax.lax.conv_general_dilated(
        x,
        kernel,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return apply_activation(out + bias[None, None, None, :], activation)


def conv2d_transpose_tied(
    x: jnp.ndarray,
    kernel: jnp.ndarray,
    bias: jnp.ndarray,
    stride: int = 2,
    activation: Optional[str] = None,
) -> jnp.ndarray:
    """Oracle for tied transposed conv: zero-stuff to (sH, sW), then a
    SAME conv with the spatially-flipped, channel-swapped kernel.

    The dilation is written with jnp indexing while the convolution
    uses lax -- so the Pallas conv kernel is still checked against an
    independent implementation.  ``kernel`` is the encoder's
    (3,3,Cin,Cout); the transpose maps Cout -> Cin, matching
    :func:`conv2d.conv2d_transpose_tied`.
    """
    b_, h, w, c = x.shape
    if stride > 1:
        dil = jnp.zeros((b_, h * stride, w * stride, c), dtype=x.dtype)
        dil = dil.at[:, ::stride, ::stride, :].set(x)
    else:
        dil = x
    k_t = jnp.flip(kernel, axis=(0, 1)).transpose(0, 1, 3, 2)
    return conv2d_same(dil, k_t, bias, activation)


def layernorm(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """Oracle for :func:`layernorm.layernorm` (normalise trailing axis)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for :func:`conv2d.maxpool2x2` via reduce_window."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
