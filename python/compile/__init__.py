"""Build-time Python for the cogsim-disagg reproduction.

This package is only ever executed by ``make artifacts`` (and pytest).
It authors the surrogate models (Layer 2, JAX) and their compute
kernels (Layer 1, Pallas), and AOT-lowers every (model, batch-size)
pair to HLO text that the Rust coordinator loads via PJRT.  Nothing in
here runs on the request path.
"""
