"""fused_linear kernel vs the pure-jnp oracle.

Hypothesis sweeps the (M, K, N, activation) space the CogSim models
actually visit -- odd small batches (the latency-bound regime from the
paper), MXU-misaligned widths, and every fused activation.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fused_linear as fl
from compile.kernels import ref

from .conftest import assert_close


def _run(m, k, n, activation, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1 / np.sqrt(k), size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    out = fl.fused_linear(x, w, b, activation=activation)
    assert_close(out, ref.linear(x, w, b, activation))
    return out


@pytest.mark.parametrize("activation", [None, "relu", "sigmoid", "tanh"])
def test_activations(activation):
    _run(8, 42, 19, activation)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 42, 19),      # Hermit encoder entry at batch 1 (latency regime)
        (1, 1024, 2050),  # Hermit's widest layer at batch 1
        (4, 4608, 64),    # MIR FC entry
        (64, 64, 4608),   # MIR FC exit
        (256, 42, 19),    # batched encoder
        (3, 7, 5),        # nothing aligned
        (128, 128, 128),  # exactly one tile
        (129, 128, 129),  # one row/col over a tile
    ],
)
def test_shapes(m, k, n):
    _run(m, k, n, "relu")


def test_block_overrides():
    _run_block = fl.fused_linear(
        jnp.ones((10, 20), jnp.float32),
        jnp.ones((20, 30), jnp.float32),
        jnp.zeros((30,), jnp.float32),
        block_m=8,
        block_n=128,
    )
    assert_close(_run_block, np.full((10, 30), 20.0))


def test_shape_mismatch_raises():
    x = jnp.ones((4, 5), jnp.float32)
    w = jnp.ones((6, 7), jnp.float32)
    b = jnp.zeros((7,), jnp.float32)
    with pytest.raises(ValueError, match="contraction mismatch"):
        fl.fused_linear(x, w, b)


def test_bias_mismatch_raises():
    x = jnp.ones((4, 5), jnp.float32)
    w = jnp.ones((5, 7), jnp.float32)
    with pytest.raises(ValueError, match="bias shape"):
        fl.fused_linear(x, w, jnp.zeros((6,), jnp.float32))


def test_dtype_preserved():
    out = _run(5, 11, 13, "relu")
    assert out.dtype == jnp.float32


def test_zero_batch_edgecase():
    # M=0 is legal for a drained batcher; result must be (0, N).
    x = jnp.zeros((0, 8), jnp.float32)
    w = jnp.ones((8, 3), jnp.float32)
    b = jnp.zeros((3,), jnp.float32)
    out = fl.fused_linear(x, w, b)
    assert out.shape == (0, 3)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 96),
    n=st.integers(1, 160),
    activation=st.sampled_from([None, "relu", "sigmoid", "tanh"]),
)
def test_hypothesis_sweep(m, k, n, activation):
    _run(m, k, n, activation, seed=m * 7 + k * 3 + n)


def test_vmem_estimate_within_budget():
    # The largest Hermit layer tile must fit VMEM comfortably.
    assert fl.vmem_bytes(128, 1024, 2050) < 4 * 1024 * 1024
    # And the MIR FC layers.
    assert fl.vmem_bytes(128, 4608, 64) < 8 * 1024 * 1024
