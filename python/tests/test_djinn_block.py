"""djinn_chain fused-chain kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import djinn_block as db
from compile.kernels import ref

from .conftest import assert_close


def _make_chain(widths, seed=0):
    rng = np.random.default_rng(seed)
    params = []
    for i in range(len(widths) - 1):
        params.append(
            jnp.asarray(
                rng.normal(0, 1 / np.sqrt(widths[i]), size=(widths[i], widths[i + 1])),
                jnp.float32,
            )
        )
        params.append(jnp.asarray(rng.normal(size=(widths[i + 1],)) * 0.1, jnp.float32))
    return tuple(params)


def _run(m, widths, activations, seed=0):
    rng = np.random.default_rng(seed + 99)
    x = jnp.asarray(rng.normal(size=(m, widths[0])), jnp.float32)
    params = _make_chain(widths, seed)
    out = db.djinn_chain(x, params, activations=tuple(activations))
    assert_close(out, ref.chain(x, params, activations), rtol=3e-4, atol=3e-4)


def test_single_layer():
    _run(4, [10, 20], ["relu"])


def test_hermit_encoder_shape():
    _run(1, [42, 19, 17, 13, 10], ["relu"] * 4)


def test_hermit_decoder_shape():
    _run(7, [2050, 27, 27, 27, 27, 27, 30], ["relu"] * 5 + [None])


def test_hermit_djinn_trunk_batch1():
    # The full 11-layer trunk at the paper's critical batch size.
    _run(1, [10, 12, 16, 24, 32, 48, 64, 128, 256, 512, 1024, 2050], ["relu"] * 11)


def test_mixed_activations():
    _run(5, [8, 16, 8], ["tanh", "sigmoid"])


def test_batch_tiling_boundary():
    # 129 rows with the default 128 tile exercises the padded tail.
    _run(129, [16, 32, 8], ["relu", None])


def test_param_arity_validation():
    x = jnp.ones((2, 4), jnp.float32)
    with pytest.raises(ValueError, match=r"\(w, b\) pairs"):
        db.djinn_chain(x, (jnp.ones((4, 4), jnp.float32),), activations=("relu",))


def test_activation_count_validation():
    x = jnp.ones((2, 4), jnp.float32)
    params = _make_chain([4, 4])
    with pytest.raises(ValueError, match="activations for"):
        db.djinn_chain(x, params, activations=("relu", "relu"))


def test_chain_shape_validation():
    x = jnp.ones((2, 4), jnp.float32)
    rng = np.random.default_rng(0)
    params = (
        jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        jnp.zeros((8,), jnp.float32),
        jnp.asarray(rng.normal(size=(9, 4)), jnp.float32),  # does not chain
        jnp.zeros((4,), jnp.float32),
    )
    with pytest.raises(ValueError, match="does not chain"):
        db.djinn_chain(x, params, activations=("relu", None))


def test_vmem_budget_enforced():
    # A chain too fat to fuse must be rejected, not silently spilled.
    widths = [4096, 4096, 4096]
    assert not db.fits_vmem(widths)
    x = jnp.ones((2, 4096), jnp.float32)
    params = _make_chain(widths)
    with pytest.raises(ValueError, match="VMEM budget"):
        db.djinn_chain(x, params, activations=("relu", None))


def test_hermit_trunk_fits_vmem():
    # The design claim: the whole DJINN trunk fuses within budget.
    assert db.fits_vmem([10, 12, 16, 24, 32, 48, 64, 128, 256, 512, 1024, 2050])


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    widths=st.lists(st.integers(1, 64), min_size=2, max_size=5),
    act=st.sampled_from(["relu", "tanh", None]),
)
def test_hypothesis_chains(m, widths, act):
    _run(m, widths, [act] * (len(widths) - 1), seed=sum(widths) + m)
