"""Shared fixtures for the kernel/model test suite."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def assert_close(actual, expected, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(
        np.asarray(actual), np.asarray(expected), rtol=rtol, atol=atol
    )
