"""layernorm kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import layernorm as ln
from compile.kernels import ref

from .conftest import assert_close


def _data(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(2.0, 3.0, size=shape), jnp.float32)
    g = jnp.asarray(rng.normal(1.0, 0.2, size=(shape[-1],)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(shape[-1],)) * 0.5, jnp.float32)
    return x, g, b


@pytest.mark.parametrize(
    "shape",
    [
        (1, 16),            # single row
        (7, 128),           # MXU-aligned channels
        (4, 24, 24, 16),    # MIR post-conv NHWC
        (2, 6, 6, 128),     # MIR deepest feature map
        (130, 5),           # batch crosses the 128 tile
    ],
)
def test_shapes(shape):
    x, g, b = _data(shape, seed=sum(shape))
    assert_close(ln.layernorm(x, g, b), ref.layernorm(x, g, b))


def test_normalisation_property():
    # With gamma=1, beta=0 each row must be ~zero-mean unit-variance.
    x, _, _ = _data((32, 64), seed=9)
    out = ln.layernorm(x, jnp.ones((64,)), jnp.zeros((64,)))
    assert np.allclose(np.mean(out, axis=-1), 0.0, atol=1e-5)
    assert np.allclose(np.std(out, axis=-1), 1.0, atol=1e-3)


def test_constant_row_stability():
    # A constant row has zero variance; eps must keep it finite.
    x = jnp.full((3, 10), 5.0, jnp.float32)
    out = ln.layernorm(x, jnp.ones((10,)), jnp.zeros((10,)))
    assert np.all(np.isfinite(np.asarray(out)))
    assert_close(out, np.zeros((3, 10)), atol=1e-3)


def test_param_shape_validation():
    x = jnp.ones((2, 8), jnp.float32)
    with pytest.raises(ValueError, match="gamma/beta"):
        ln.layernorm(x, jnp.ones((7,)), jnp.zeros((8,)))


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 64), d=st.integers(2, 96))
def test_hypothesis_sweep(rows, d):
    x, g, b = _data((rows, d), seed=rows * 31 + d)
    assert_close(ln.layernorm(x, g, b), ref.layernorm(x, g, b))
