"""Training-loop checks: loss decreases, step is jittable, Adam state
shapes match."""

import jax.numpy as jnp
import numpy as np

from compile import train
from compile.models import mir
from compile.models.common import flat_arrays


def test_loss_decreases_in_40_steps():
    names, params, curve = train.train(steps=40, batch=16, seed=1, log_every=100)
    assert len(curve) == 40
    # BCE must drop measurably from the random-init plateau
    assert curve[-1] < curve[0] * 0.9, f"{curve[0]} -> {curve[-1]}"
    assert all(np.isfinite(curve))


def test_trained_params_keep_shapes_and_names():
    names, params, _ = train.train(steps=2, batch=4, seed=0, log_every=100)
    ref = mir.init_params(0)
    assert names == [n for n, _ in ref]
    for p, (_, a) in zip(params, ref):
        assert p.shape == a.shape


def test_loss_fn_matches_pallas_forward():
    # the training loss differentiates forward_ref; the served model is
    # the Pallas forward — they must agree on the loss value too.
    params = [jnp.asarray(a) for a in flat_arrays(mir.init_params(3))]
    x = jnp.asarray(mir.sample_input(2, seed=5))
    ref_loss = float(train.loss_fn(params, x))

    recon = mir.forward(x, *params)
    eps = 1e-6
    recon = jnp.clip(recon, eps, 1.0 - eps)
    pallas_loss = float(
        jnp.mean(-(x * jnp.log(recon) + (1 - x) * jnp.log(1 - recon)))
    )
    assert abs(ref_loss - pallas_loss) < 1e-4


def test_batch_generator_in_range():
    rng = np.random.default_rng(0)
    x = train.make_batch(rng, 8)
    assert x.shape == (8, 48, 48, 1)
    assert 0.0 <= x.min() and x.max() <= 1.0
