"""AOT pipeline checks: manifest integrity, HLO round-trip, weights.

These tests lower small-batch artifacts into a tmpdir (independent of
``make artifacts``) and verify the contracts the Rust runtime relies
on: parameter ordering, manifest shapes, HLO parameter arity, and that
the lowered computation reproduces the Python forward exactly when run
through jax's own executor.
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.models import REGISTRY
from compile.models.common import flat_arrays

from .conftest import assert_close


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = {"dtype": aot.DTYPE, "seed": 0, "models": {}}
    manifest["models"]["hermit"] = aot.lower_model("hermit", [1, 4], out)
    manifest["models"]["mir"] = aot.lower_model("mir", [1], out)
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out


def test_manifest_structure(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    assert m["dtype"] == "f32"
    h = m["models"]["hermit"]
    assert h["input_shape"] == [42]
    assert h["output_shape"] == [30]
    assert [b["batch"] for b in h["batches"]] == [1, 4]
    assert h["param_count"] > 2_700_000


def test_param_names_sorted_is_calling_order(artifacts):
    # Rust loads weights by lexicographic name; that MUST equal the
    # calling convention order.
    m = json.loads((artifacts / "manifest.json").read_text())
    for entry in m["models"].values():
        names = [p["name"] for p in entry["params"]]
        assert names == sorted(names)


def test_weights_npz_matches_manifest(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    entry = m["models"]["hermit"]
    with np.load(artifacts / entry["weights_file"]) as z:
        assert set(z.files) == {p["name"] for p in entry["params"]}
        for p in entry["params"]:
            assert list(z[p["name"]].shape) == p["shape"]
            assert z[p["name"]].dtype == np.float32


def test_hlo_files_exist_and_parse_arity(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    entry = m["models"]["hermit"]
    n_params = len(entry["params"])
    for b in entry["batches"]:
        text = (artifacts / b["hlo_file"]).read_text()
        assert "ENTRY" in text
        # 1 input + n_params parameters in the entry computation.
        assert text.count("parameter(") >= n_params + 1


def test_hlo_text_parses_back(artifacts):
    """The dumped HLO text must re-parse through XLA's own text parser
    (the same parser the Rust runtime's ``HloModuleProto::from_text_file``
    uses); full execute-and-compare happens in rust/tests/runtime.rs."""
    from jax._src.lib import xla_client as xc

    m = json.loads((artifacts / "manifest.json").read_text())
    for entry in m["models"].values():
        for b in entry["batches"]:
            text = (artifacts / b["hlo_file"]).read_text()
            mod = xc._xla.hlo_module_from_text(text)
            # proto serialization must succeed (structure is complete)
            assert len(mod.as_serialized_hlo_module_proto()) > 0


def test_entry_signature_shapes(artifacts):
    """The ENTRY computation's parameter list must match the manifest:
    param 0 is the (batch, *input_shape) activation, then the weights
    in calling-convention order."""
    m = json.loads((artifacts / "manifest.json").read_text())
    entry = m["models"]["hermit"]
    text = (artifacts / "hermit_b4.hlo.txt").read_text()
    # x: f32[4,42]
    assert "f32[4,42]" in text
    # widest DJINN weight: f32[1024,2050]
    assert "f32[1024,2050]" in text
    # output tuple: (f32[4,30])
    assert "f32[4,30]" in text


def test_weights_sha_recorded(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    for entry in m["models"].values():
        assert len(entry["weights_sha256"]) == 64
