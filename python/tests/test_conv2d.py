"""conv2d / transposed-conv / maxpool kernels vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conv2d as cv
from compile.kernels import ref

from .conftest import assert_close


def _data(b, h, w, cin, cout, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, h, w, cin)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.3, size=(3, 3, cin, cout)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(cout,)) * 0.1, jnp.float32)
    return x, k, bias


@pytest.mark.parametrize(
    "b,h,w,cin,cout",
    [
        (1, 48, 48, 1, 16),   # MIR first conv at batch 1
        (2, 24, 24, 16, 32),  # MIR second conv
        (3, 6, 6, 64, 128),   # MIR deepest conv
        (9, 8, 8, 4, 4),      # batch not a multiple of the tile
        (1, 4, 4, 1, 1),      # minimal
    ],
)
def test_conv_shapes(b, h, w, cin, cout):
    x, k, bias = _data(b, h, w, cin, cout, seed=b + h)
    out = cv.conv2d_same(x, k, bias, activation="relu")
    assert_close(out, ref.conv2d_same(x, k, bias, "relu"))


@pytest.mark.parametrize("activation", [None, "relu", "sigmoid"])
def test_conv_activations(activation):
    x, k, bias = _data(2, 8, 8, 3, 5)
    out = cv.conv2d_same(x, k, bias, activation=activation)
    assert_close(out, ref.conv2d_same(x, k, bias, activation))


def test_conv_kernel_mismatch_raises():
    x, k, bias = _data(1, 8, 8, 3, 5)
    with pytest.raises(ValueError, match="does not match input"):
        cv.conv2d_same(x, k[:, :, :2], bias)


def test_conv_bias_mismatch_raises():
    x, k, _ = _data(1, 8, 8, 3, 5)
    with pytest.raises(ValueError, match="bias"):
        cv.conv2d_same(x, k, jnp.zeros((4,), jnp.float32))


@pytest.mark.parametrize("stride", [1, 2])
def test_transpose_tied(stride):
    x, k, _ = _data(2, 6, 6, 4, 8, seed=3)
    # tied transpose maps Cout(8) back to Cin(4)
    up = jnp.asarray(np.random.default_rng(5).normal(size=(2, 6, 6, 8)), jnp.float32)
    bias = jnp.zeros((4,), jnp.float32)
    out = cv.conv2d_transpose_tied(up, k, bias, stride=stride, activation="relu")
    rout = ref.conv2d_transpose_tied(up, k, bias, stride, "relu")
    assert out.shape == (2, 6 * stride, 6 * stride, 4)
    assert_close(out, rout)


def test_transpose_channel_mismatch_raises():
    x, k, _ = _data(1, 6, 6, 4, 8)
    bad = jnp.ones((1, 6, 6, 5), jnp.float32)
    with pytest.raises(ValueError, match="tied kernel"):
        cv.conv2d_transpose_tied(bad, k, jnp.zeros((4,), jnp.float32))


def test_maxpool():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 8, 8, 5)), jnp.float32)
    assert_close(cv.maxpool2x2(x), ref.maxpool2x2(x))


def test_maxpool_odd_raises():
    with pytest.raises(ValueError, match="even"):
        cv.maxpool2x2(jnp.ones((1, 7, 8, 1), jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 10),
    hw=st.sampled_from([4, 6, 8, 12]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
)
def test_hypothesis_conv(b, hw, cin, cout):
    x, k, bias = _data(b, hw, hw, cin, cout, seed=b * 13 + cin)
    out = cv.conv2d_same(x, k, bias)
    assert_close(out, ref.conv2d_same(x, k, bias))
