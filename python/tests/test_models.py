"""Hermit + MIR model-level checks: paper geometry, Pallas vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import REGISTRY, hermit, mir
from compile.models.common import flat_arrays, param_count

from .conftest import assert_close


def _flat(model, seed=0):
    return [jnp.asarray(a) for a in flat_arrays(model.init_params(seed))]


# ---------------------------------------------------------------- hermit
class TestHermit:
    def test_layer_count_matches_paper(self):
        # "consists of 21 fully connected layers across 3 sub-structures"
        assert hermit.N_LAYERS == 21

    def test_substructure_geometry(self):
        # encoder: 4 layers, max hidden width 19
        assert len(hermit.ENCODER_WIDTHS) - 1 == 4
        assert max(hermit.ENCODER_WIDTHS[1:]) == 19
        # DJINN: 11 layers, max width 2050
        assert len(hermit.DJINN_WIDTHS) - 1 == 11
        assert max(hermit.DJINN_WIDTHS) == 2050
        # decoder: 6 layers, max hidden width 27
        assert len(hermit.DECODER_WIDTHS) - 1 == 6
        assert max(hermit.DECODER_WIDTHS[1:-1]) == 27
        # input: 42 values per sample
        assert hermit.INPUT_SIZE == 42

    def test_param_budget(self):
        n = param_count(hermit.init_params(0))
        lo, hi = hermit.PARAM_COUNT_RANGE
        assert lo <= n <= hi, f"{n} outside paper budget (~2.8M)"

    def test_param_init_deterministic(self):
        a = hermit.init_params(0)
        b = hermit.init_params(0)
        for (na, pa), (nb, pb) in zip(a, b):
            assert na == nb
            np.testing.assert_array_equal(pa, pb)

    @pytest.mark.parametrize("batch", [1, 4, 16])
    def test_forward_matches_ref(self, batch):
        flat = _flat(hermit)
        x = jnp.asarray(hermit.sample_input(batch))
        assert_close(
            hermit.forward(x, *flat),
            hermit.forward_ref(x, *flat),
            rtol=3e-4,
            atol=3e-4,
        )

    def test_output_shape(self):
        flat = _flat(hermit)
        x = jnp.asarray(hermit.sample_input(3))
        assert hermit.forward(x, *flat).shape == (3, hermit.OUTPUT_SIZE)

    def test_forward_deterministic(self):
        flat = _flat(hermit)
        x = jnp.asarray(hermit.sample_input(2))
        np.testing.assert_array_equal(
            hermit.forward(x, *flat), hermit.forward(x, *flat)
        )


# ------------------------------------------------------------------- mir
class TestMIR:
    def test_param_budget(self):
        n = param_count(mir.init_params(0))
        lo, hi = mir.PARAM_COUNT_RANGE
        assert lo <= n <= hi, f"{n} outside paper budget (~700K)"

    def test_fc_width_matches_paper(self):
        # "3 fully connected layers, two of which with 4608 neurons each"
        assert mir.FLAT == 4608

    def test_conv_count(self):
        # "4 convolution layers with pooling, layernorm after every conv"
        assert len(mir.CHANNELS) - 1 == 4

    @pytest.mark.parametrize("batch", [1, 3])
    def test_forward_matches_ref(self, batch):
        flat = _flat(mir)
        x = jnp.asarray(mir.sample_input(batch))
        assert_close(
            mir.forward(x, *flat), mir.forward_ref(x, *flat), rtol=3e-4, atol=3e-4
        )

    def test_autoencoder_shape_roundtrip(self):
        flat = _flat(mir)
        x = jnp.asarray(mir.sample_input(2))
        y = mir.forward(x, *flat)
        assert y.shape == x.shape

    def test_output_is_volume_fraction(self):
        # sigmoid output: every zone prediction in [0, 1]
        flat = _flat(mir)
        y = np.asarray(mir.forward(jnp.asarray(mir.sample_input(2)), *flat))
        assert y.min() >= 0.0 and y.max() <= 1.0

    def test_noln_variant_matches_ref(self):
        flat = _flat(mir.NOLN)
        x = jnp.asarray(mir.sample_input(2))
        assert_close(
            mir.NOLN.forward(x, *flat),
            mir.NOLN.forward_ref(x, *flat),
            rtol=3e-4,
            atol=3e-4,
        )

    def test_noln_has_fewer_params(self):
        assert param_count(mir.NOLN.init_params(0)) < param_count(mir.init_params(0))

    def test_sample_input_is_volume_fraction(self):
        x = mir.sample_input(4)
        assert x.shape == (4, mir.IMG, mir.IMG, 1)
        assert x.min() >= 0.0 and x.max() <= 1.0


def test_registry_complete():
    assert set(REGISTRY) == {"hermit", "mir", "mir_noln"}
    for name, model in REGISTRY.items():
        assert hasattr(model, "forward") and hasattr(model, "init_params"), name
